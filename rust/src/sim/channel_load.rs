//! Analytical channel-load model (Sec. IV-C, Fig. 8–12, Fig. 15).
//!
//! Each flow's per-interval volume is accumulated on every link of its
//! route. The *worst-case channel load* is the busiest link's words per
//! interval; with one word per cycle per link, the NoC needs that many
//! cycles to drain an interval's traffic, so the communication-side
//! interval delay is `worst_load / link_bw`. Congestion happens when that
//! exceeds the compute interval ("if this time is less, it leads to
//! congestion ... latency is limited by the hop count rather than the
//! compute interval").

use crate::config::ArchConfig;
use crate::noc::{route_into, Topology};
use crate::traffic::Flow;

/// Result of routing a flow set over a topology.
#[derive(Debug, Clone)]
pub struct LoadAnalysis {
    /// Words per interval per link (dense, indexed by `LinkId`).
    pub per_link_words: Vec<f64>,
    /// Max over links — the worst-case channel load of Fig. 15.
    pub worst_channel_load: f64,
    /// Σ over flows of words × hops — total traffic work.
    pub total_word_hops: f64,
    /// Σ over flows of words × wire length (express links count their
    /// physical span) — the hop-energy proxy.
    pub total_word_wire: f64,
    /// Largest hop count of any flow (latency lower bound for one word).
    pub max_route_hops: usize,
}

/// Route every flow and accumulate link loads.
pub fn analyze(topo: &Topology, flows: &[Flow]) -> LoadAnalysis {
    let mut per_link = vec![0f64; topo.num_links()];
    let mut word_hops = 0f64;
    let mut word_wire = 0f64;
    let mut max_hops = 0usize;
    let mut buf = Vec::with_capacity(64);
    for f in flows {
        buf.clear();
        route_into(topo, f.src, f.dst, &mut buf);
        max_hops = max_hops.max(buf.len());
        word_hops += f.words_per_interval * buf.len() as f64;
        for &lid in &buf {
            per_link[lid as usize] += f.words_per_interval;
            word_wire += f.words_per_interval * topo.link(lid).length as f64;
        }
    }
    let worst = per_link.iter().cloned().fold(0.0, f64::max);
    LoadAnalysis {
        per_link_words: per_link,
        worst_channel_load: worst,
        total_word_hops: word_hops,
        total_word_wire: word_wire,
        max_route_hops: max_hops,
    }
}

impl LoadAnalysis {
    /// Number of links carrying any traffic.
    pub fn active_links(&self) -> usize {
        self.per_link_words.iter().filter(|&&w| w > 0.0).count()
    }

    /// Congestion factor relative to a compute interval: >1 means the NoC
    /// is the bottleneck.
    pub fn congestion_factor(&self, compute_interval: f64, link_words_per_cycle: f64) -> f64 {
        if compute_interval <= 0.0 {
            return f64::INFINITY;
        }
        (self.worst_channel_load / link_words_per_cycle) / compute_interval
    }
}

/// Communication-side delay of one pipeline interval in cycles.
pub fn interval_comm_delay(analysis: &LoadAnalysis, cfg: &ArchConfig) -> f64 {
    // Serialization on the busiest channel dominates; a single word's
    // route latency matters only when loads are tiny.
    let serialization = analysis.worst_channel_load / cfg.link_words_per_cycle;
    serialization.max(analysis.max_route_hops as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::spatial::{Organization, Placement};
    use crate::traffic::{derive_flows, scenarios, StageHandoff};

    fn mesh(rows: usize, cols: usize) -> Topology {
        Topology::new(TopologyKind::Mesh, rows, cols)
    }

    #[test]
    fn single_flow_loads_whole_route() {
        let t = mesh(4, 4);
        let flows = vec![Flow {
            src: t.node(0, 0),
            dst: t.node(0, 3),
            words_per_interval: 2.0,
            class: crate::traffic::FlowClass::Pipeline {
                from_stage: 0,
                to_stage: 1,
            },
        }];
        let a = analyze(&t, &flows);
        assert_eq!(a.active_links(), 3);
        assert_eq!(a.worst_channel_load, 2.0);
        assert_eq!(a.total_word_hops, 6.0);
        assert_eq!(a.max_route_hops, 3);
    }

    #[test]
    fn fig8_blocked_congests_on_boundary() {
        // Fig. 8: blocked 1-D on a mesh — overlapping row paths pile load
        // onto the boundary columns; worst channel load ≈ half the row
        // width (every producer in a row shares the same eastward path).
        let s = scenarios::fig8_depth2_blocked(32, 32);
        let t = mesh(32, 32);
        let flows = derive_flows(&t, &s.placement, &s.handoffs);
        let a = analyze(&t, &flows);
        // words/interval = 512 (one per producer PE); 16 producers per row
        // funnel over each row's boundary link → load 16 words/interval.
        assert!(
            (a.worst_channel_load - 16.0).abs() < 1e-9,
            "worst = {}",
            a.worst_channel_load
        );
        // Congested at compute interval 2 (factor 8 — the Fig. 15 example:
        // "For compute interval of 2 cycles, the overall communication
        // delay increases by a factor of 8").
        assert!((a.congestion_factor(2.0, 1.0) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn fig10_striped_is_congestion_free() {
        let s = scenarios::fig10_striped(32, 32);
        let t = mesh(32, 32);
        let flows = derive_flows(&t, &s.placement, &s.handoffs);
        let a = analyze(&t, &flows);
        // Single-hop neighbor traffic: worst load = 1 word/interval.
        assert!(a.worst_channel_load <= 1.0 + 1e-9, "{}", a.worst_channel_load);
        assert!(a.congestion_factor(2.0, 1.0) <= 1.0);
    }

    #[test]
    fn fig9a_skip_doubles_boundary_load() {
        let t = mesh(32, 32);
        let base = scenarios::fig8_depth2_blocked(32, 32);
        let skip = scenarios::fig9a_skip_blocked(32, 32);
        let a_base = analyze(&t, &derive_flows(&t, &base.placement, &base.handoffs));
        let a_skip = analyze(&t, &derive_flows(&t, &skip.placement, &skip.handoffs));
        assert!(
            (a_skip.worst_channel_load / a_base.worst_channel_load - 2.0).abs() < 1e-9
        );
    }

    #[test]
    fn fig12_amp_reduces_congestion_and_hops() {
        // Same blocked scenario on mesh vs AMP (Fig. 12b).
        let s = scenarios::fig8_depth2_blocked(32, 32);
        let mesh_t = mesh(32, 32);
        let amp_t = Topology::new(TopologyKind::Amp, 32, 32);
        let fm = derive_flows(&mesh_t, &s.placement, &s.handoffs);
        let fa = derive_flows(&amp_t, &s.placement, &s.handoffs);
        let am = analyze(&mesh_t, &fm);
        let aa = analyze(&amp_t, &fa);
        assert!(
            aa.worst_channel_load < am.worst_channel_load / 2.0,
            "amp {} mesh {}",
            aa.worst_channel_load,
            am.worst_channel_load
        );
        assert!(aa.total_word_hops < am.total_word_hops);
    }

    #[test]
    fn unequal_allocation_hotspot_at_boundary() {
        let s = scenarios::fig9b_unequal_blocked(32, 32);
        let t = mesh(32, 32);
        let flows = derive_flows(&t, &s.placement, &s.handoffs);
        let a = analyze(&t, &flows);
        // Hotspot exists but with fewer producers (3 cols) the absolute
        // load is below the equal-split case relative to its words.
        assert!(a.worst_channel_load > 1.0);
        // The busiest link sits at the stage boundary (col 2→3 eastward).
        let (max_idx, _) = a
            .per_link_words
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap();
        let link = t.link(max_idx as u32);
        let (_, sc) = t.coords(link.from);
        let (_, dc) = t.coords(link.to);
        assert!(dc > sc, "hotspot flows eastward");
    }

    #[test]
    fn checkerboard_cuts_2d_blocked_traffic() {
        let t = mesh(32, 32);
        let blocked = scenarios::fig11_blocked2d(32, 32, true);
        let inter = scenarios::fig11_checkerboard(32, 32, true);
        let ab = analyze(&t, &derive_flows(&t, &blocked.placement, &blocked.handoffs));
        let ai = analyze(&t, &derive_flows(&t, &inter.placement, &inter.handoffs));
        assert!(ai.total_word_hops < ab.total_word_hops / 2.0);
        assert!(ai.worst_channel_load <= ab.worst_channel_load);
    }

    #[test]
    fn interval_comm_delay_floor_is_route_latency() {
        let t = mesh(8, 8);
        let cfg = ArchConfig::default();
        let flows = vec![Flow {
            src: t.node(0, 0),
            dst: t.node(7, 7),
            words_per_interval: 0.1,
            class: crate::traffic::FlowClass::Pipeline {
                from_stage: 0,
                to_stage: 1,
            },
        }];
        let a = analyze(&t, &flows);
        // tiny volume: latency floor = 14 hops
        assert_eq!(interval_comm_delay(&a, &cfg), 14.0);
    }

    #[test]
    fn empty_flows_zero_analysis() {
        let t = mesh(4, 4);
        let a = analyze(&t, &[]);
        assert_eq!(a.worst_channel_load, 0.0);
        assert_eq!(a.active_links(), 0);
        assert_eq!(a.max_route_hops, 0);
    }

    #[test]
    fn blocked1d_placement_loads_match_flow_conservation() {
        // total word-hops equals Σ flow words × hops — cross-check against
        // per-link sum.
        let t = mesh(16, 16);
        let p = Placement::build(16, 16, Organization::Blocked1D, &[1, 1]);
        let flows = derive_flows(&t, &p, &[StageHandoff::pipeline(0, 1, 128.0)]);
        let a = analyze(&t, &flows);
        let link_sum: f64 = a.per_link_words.iter().sum();
        assert!((link_sum - a.total_word_hops).abs() < 1e-6);
    }
}
