//! Cycle-level queueing NoC simulator.
//!
//! Validates the analytical channel-load model: all of one interval's
//! traffic is injected at cycle 0, links forward one word per cycle
//! (`link_words_per_cycle` rounded to ≥1) with FIFO arbitration, and the
//! simulator reports the cycle at which the last word is delivered. The
//! analytic worst-case channel load is a lower bound on this; for the
//! regular traffic patterns of this paper the two agree closely.

use std::collections::VecDeque;

use crate::noc::{route, LinkId, Topology};
use crate::traffic::Flow;

/// Result of simulating one pipeline interval's traffic.
#[derive(Debug, Clone, PartialEq)]
pub struct CycleSimResult {
    /// Cycle at which the last word arrived (= interval comm delay).
    pub makespan: u64,
    /// Total words delivered.
    pub words_delivered: u64,
    /// Mean per-word latency in cycles.
    pub mean_latency: f64,
}

struct Packet {
    route: Vec<LinkId>,
    hop: usize,
    injected: u64,
}

/// Simulate the delivery of `flows` (volumes rounded up to whole words).
///
/// `words_per_cycle` is the per-link bandwidth (≥ 1 word granularity).
pub fn simulate_interval(topo: &Topology, flows: &[Flow], words_per_cycle: usize) -> CycleSimResult {
    let wpc = words_per_cycle.max(1);
    let mut packets: Vec<Packet> = Vec::new();
    for f in flows {
        let words = f.words_per_interval.ceil() as u64;
        if words == 0 || f.src == f.dst {
            continue;
        }
        let r = route(topo, f.src, f.dst);
        for _ in 0..words {
            packets.push(Packet {
                route: r.clone(),
                hop: 0,
                injected: 0,
            });
        }
    }
    if packets.is_empty() {
        return CycleSimResult {
            makespan: 0,
            words_delivered: 0,
            mean_latency: 0.0,
        };
    }

    // FIFO queue per link of packet indices waiting to traverse it.
    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); topo.num_links()];
    for (i, p) in packets.iter().enumerate() {
        queues[p.route[0] as usize].push_back(i);
    }
    let total = packets.len() as u64;
    let mut delivered = 0u64;
    let mut latency_sum = 0u64;
    let mut cycle = 0u64;
    // Safety valve: regular patterns finish well under this.
    let max_cycles = 10_000_000u64;
    while delivered < total {
        cycle += 1;
        assert!(cycle < max_cycles, "cycle sim did not converge");
        // Each link forwards up to wpc packets this cycle; collect moves
        // first so a packet moves at most one hop per cycle.
        let mut moves: Vec<(usize, Option<LinkId>)> = Vec::new();
        for q in queues.iter_mut() {
            for _ in 0..wpc {
                let Some(pi) = q.pop_front() else { break };
                let p = &packets[pi];
                let next_hop = p.hop + 1;
                if next_hop >= p.route.len() {
                    moves.push((pi, None)); // delivered after this hop
                } else {
                    moves.push((pi, Some(p.route[next_hop])));
                }
            }
        }
        for (pi, next) in moves {
            packets[pi].hop += 1;
            match next {
                None => {
                    delivered += 1;
                    latency_sum += cycle - packets[pi].injected;
                }
                Some(link) => queues[link as usize].push_back(pi),
            }
        }
    }
    CycleSimResult {
        makespan: cycle,
        words_delivered: delivered,
        mean_latency: latency_sum as f64 / total as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::sim::analyze;
    use crate::traffic::{derive_flows, scenarios, FlowClass};

    fn flow(t: &Topology, s: (usize, usize), d: (usize, usize), w: f64) -> Flow {
        Flow {
            src: t.node(s.0, s.1),
            dst: t.node(d.0, d.1),
            words_per_interval: w,
            class: FlowClass::Pipeline {
                from_stage: 0,
                to_stage: 1,
            },
        }
    }

    #[test]
    fn single_word_latency_is_hop_count() {
        let t = Topology::new(TopologyKind::Mesh, 8, 8);
        let r = simulate_interval(&t, &[flow(&t, (0, 0), (0, 5), 1.0)], 1);
        assert_eq!(r.makespan, 5);
        assert_eq!(r.words_delivered, 1);
    }

    #[test]
    fn serialization_on_shared_link() {
        // Two flows share the same single link: 2 words, 1 word/cycle → 2
        // cycles.
        let t = Topology::new(TopologyKind::Mesh, 2, 2);
        let flows = vec![
            flow(&t, (0, 0), (0, 1), 1.0),
            flow(&t, (0, 0), (0, 1), 1.0),
        ];
        let r = simulate_interval(&t, &flows, 1);
        assert_eq!(r.makespan, 2);
    }

    #[test]
    fn higher_bandwidth_shortens_makespan() {
        let t = Topology::new(TopologyKind::Mesh, 2, 2);
        let flows = vec![flow(&t, (0, 0), (0, 1), 8.0)];
        let r1 = simulate_interval(&t, &flows, 1);
        let r4 = simulate_interval(&t, &flows, 4);
        assert_eq!(r1.makespan, 8);
        assert_eq!(r4.makespan, 2);
    }

    #[test]
    fn analytic_load_lower_bounds_simulated_makespan() {
        // Validation property across the Fig. 8–11 scenario library on a
        // small array: worst-case channel load ≤ makespan ≤ load + max hops.
        for s in scenarios::all(8, 8) {
            let t = Topology::new(TopologyKind::Mesh, 8, 8);
            let flows: Vec<Flow> = derive_flows(&t, &s.placement, &s.handoffs)
                .into_iter()
                // The simulator moves whole words; round volumes up so the
                // analytic model sees the same integer traffic.
                .map(|f| Flow {
                    words_per_interval: f.words_per_interval.ceil(),
                    ..f
                })
                .collect();
            if flows.is_empty() {
                continue;
            }
            let a = analyze(&t, &flows);
            let sim = simulate_interval(&t, &flows, 1);
            let lower = a.worst_channel_load.floor();
            let upper = a.worst_channel_load + a.max_route_hops as f64 + 1.0;
            assert!(
                sim.makespan as f64 >= lower,
                "{}: makespan {} < load {}",
                s.name,
                sim.makespan,
                a.worst_channel_load
            );
            assert!(
                (sim.makespan as f64) <= upper + sim.words_delivered as f64 * 0.05,
                "{}: makespan {} >> load {} + hops {}",
                s.name,
                sim.makespan,
                a.worst_channel_load,
                a.max_route_hops
            );
        }
    }

    #[test]
    fn amp_speeds_up_blocked_traffic_in_simulation() {
        let s = scenarios::fig8_depth2_blocked(16, 16);
        let mesh = Topology::new(TopologyKind::Mesh, 16, 16);
        let amp = Topology::new(TopologyKind::Amp, 16, 16);
        let rm = simulate_interval(&mesh, &derive_flows(&mesh, &s.placement, &s.handoffs), 1);
        let ra = simulate_interval(&amp, &derive_flows(&amp, &s.placement, &s.handoffs), 1);
        assert!(
            ra.makespan < rm.makespan,
            "amp {} mesh {}",
            ra.makespan,
            rm.makespan
        );
    }

    #[test]
    fn empty_traffic_zero_makespan() {
        let t = Topology::new(TopologyKind::Mesh, 4, 4);
        let r = simulate_interval(&t, &[], 1);
        assert_eq!(r.makespan, 0);
    }
}
