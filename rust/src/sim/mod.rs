//! NoC simulation: analytical channel-load / congestion analysis (the
//! quantity Fig. 15 plots) and a cycle-level queueing simulator used to
//! validate the analytical model.

mod channel_load;
mod cycle_sim;

pub use channel_load::{analyze, interval_comm_delay, LoadAnalysis};
pub use cycle_sim::{simulate_interval, CycleSimResult};
