//! PE allocation by MAC ratio (Sec. IV-B: "PEs could be allocated to the
//! layers in ratios that ensure load balancing and maximum utilization").

/// Largest-remainder proportional allocation of `total` units across
/// `weights`, guaranteeing every nonzero weight at least one unit and the
/// sum exactly `total`.
pub fn proportional(weights: &[usize], total: usize) -> Vec<usize> {
    assert!(!weights.is_empty());
    assert!(
        total >= weights.iter().filter(|&&w| w > 0).count(),
        "not enough units ({total}) for {} stages",
        weights.len()
    );
    let sum: f64 = weights.iter().map(|&w| w as f64).sum();
    if sum == 0.0 {
        // Degenerate: spread evenly.
        let base = total / weights.len();
        let mut out = vec![base; weights.len()];
        let mut rem = total - base * weights.len();
        for o in out.iter_mut() {
            if rem == 0 {
                break;
            }
            *o += 1;
            rem -= 1;
        }
        return out;
    }
    let exact: Vec<f64> = weights.iter().map(|&w| w as f64 * total as f64 / sum).collect();
    let mut out: Vec<usize> = exact
        .iter()
        .zip(weights)
        .map(|(&e, &w)| {
            if w == 0 {
                0
            } else {
                (e.floor() as usize).max(1)
            }
        })
        .collect();
    let mut assigned: usize = out.iter().sum();
    // Distribute remaining units by largest fractional remainder.
    let mut order: Vec<usize> = (0..weights.len()).filter(|&i| weights[i] > 0).collect();
    order.sort_by(|&a, &b| {
        let fa = exact[a] - exact[a].floor();
        let fb = exact[b] - exact[b].floor();
        fb.partial_cmp(&fa).unwrap()
    });
    let mut i = 0;
    while assigned < total {
        out[order[i % order.len()]] += 1;
        assigned += 1;
        i += 1;
    }
    // If floors+min-1 overshot, trim from the largest allocations.
    while assigned > total {
        let max_i = (0..out.len())
            .filter(|&i| out[i] > 1)
            .max_by_key(|&i| out[i])
            .expect("cannot trim allocation below 1 per stage");
        out[max_i] -= 1;
        assigned -= 1;
    }
    out
}

/// Allocate PEs to segment stages by MAC ratio.
pub fn allocate_pes(stage_macs: &[u64], total_pes: usize) -> Vec<usize> {
    // Rescale into a ~2^20 range without destroying the MAC ordering
    // (dividing by the min would collapse distinct ratios onto the same
    // integer weight and let rounding invert dominance).
    let max = stage_macs.iter().copied().max().unwrap_or(1).max(1);
    let weights: Vec<usize> = stage_macs
        .iter()
        .map(|&m| ((m as u128 * (1 << 20) / max as u128) as usize).max(1))
        .collect();
    proportional(&weights, total_pes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_weights_split_evenly() {
        assert_eq!(proportional(&[1, 1], 8), vec![4, 4]);
        assert_eq!(proportional(&[1, 1, 1, 1], 32), vec![8, 8, 8, 8]);
    }

    #[test]
    fn ratio_1_to_9() {
        // Fig. 9b: 1×1 vs 3×3 conv MACs.
        let a = proportional(&[1, 9], 32);
        assert_eq!(a.iter().sum::<usize>(), 32);
        assert_eq!(a[0], 3); // 3.2 floored, remainder to larger
        assert_eq!(a[1], 29);
    }

    #[test]
    fn every_stage_gets_at_least_one() {
        let a = proportional(&[1, 1000], 8);
        assert!(a[0] >= 1);
        assert_eq!(a.iter().sum::<usize>(), 8);
    }

    #[test]
    fn sums_are_exact_over_random_inputs() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(77);
        for _ in 0..500 {
            let n = rng.gen_usize(1, 8);
            let weights: Vec<usize> = (0..n).map(|_| rng.gen_usize(1, 1000)).collect();
            let total = rng.gen_usize(n, 1024);
            let a = proportional(&weights, total);
            assert_eq!(a.iter().sum::<usize>(), total, "{weights:?} {total}");
            assert!(a.iter().all(|&x| x >= 1));
        }
    }

    #[test]
    fn allocate_pes_by_macs() {
        let a = allocate_pes(&[100, 900], 10);
        assert_eq!(a, vec![1, 9]);
    }

    #[test]
    fn allocation_tracks_weight_ordering() {
        let a = proportional(&[5, 3, 2], 100);
        assert!(a[0] > a[1] && a[1] > a[2]);
        assert_eq!(a.iter().sum::<usize>(), 100);
    }

    #[test]
    #[should_panic]
    fn too_few_units_panics() {
        proportional(&[1, 1, 1], 2);
    }
}
