//! Compile-time spatial-organization selection (Sec. IV-B).
//!
//! Rules, in order:
//! 1. Depth 1 → Sequential (whole array, op-by-op).
//! 2. `RF_total < granularity` → data moves through the Global Buffer; the
//!    organization is always Blocked (1-D for shallow, 2-D for deep
//!    pipelines).
//! 3. Granularity fits the RF:
//!    - finest granularities (≲ one PE's register file per producer-PE
//!      handoff) → fully interleaved (checkerboard for 2-D depths,
//!      fine-striped for shallow);
//!    - granularity near the total producer RF → blocked;
//!    - in between → fine-striped 1-D.
//! 1-D vs 2-D is decided by depth (a near-square stage grid needs 2-D once
//! depth exceeds what columns alone can host).

use crate::config::ArchConfig;

use super::placement::Organization;

/// The decision plus the quantities that drove it (for reports/tests).
#[derive(Debug, Clone, PartialEq)]
pub struct OrganizationChoice {
    pub organization: Organization,
    /// Words exchanged per interval between adjacent stages.
    pub granularity_words: u64,
    /// Words of register file across the producer's PEs.
    pub producer_rf_words: u64,
    /// True when the handoff must go through the global buffer.
    pub via_global_buffer: bool,
}

/// Pick an organization for a segment.
///
/// * `depth` — number of stages resident together (≥1).
/// * `granularity_words` — finest handoff granularity of the segment.
/// * `producer_pes` — PEs allocated to the (largest) producer stage.
pub fn choose_organization(
    cfg: &ArchConfig,
    depth: usize,
    granularity_words: u64,
    producer_pes: usize,
) -> OrganizationChoice {
    let rf_word = |bytes: u64| bytes / cfg.bytes_per_word as u64;
    let rf_per_pe = rf_word(cfg.rf_bytes_per_pe).max(1);
    let producer_rf = rf_per_pe * producer_pes.max(1) as u64;
    let deep = depth > 2; // needs a 2-D stage grid beyond 2 stages? paper
                          // uses 2-D from depth 4; depth 3 still fits 1-D.
    let two_d = depth >= 4;

    if depth <= 1 {
        return OrganizationChoice {
            organization: Organization::Sequential,
            granularity_words,
            producer_rf_words: producer_rf,
            via_global_buffer: true,
        };
    }

    // Rule 2: RF_total < granularity → GB handoff, blocked organization.
    if producer_rf < granularity_words {
        return OrganizationChoice {
            organization: if two_d {
                Organization::Blocked2D
            } else {
                Organization::Blocked1D
            },
            granularity_words,
            producer_rf_words: producer_rf,
            via_global_buffer: true,
        };
    }

    // Rule 3: granularity relative to the producer register file.
    // "Number of PEs involved on the producer side is Granularity/RF_per_PE"
    let pes_involved = crate::util::ceil_div(granularity_words, rf_per_pe);
    let organization = if pes_involved <= (producer_pes as u64).div_ceil(4) {
        // Fine granularity: a small fraction of producer PEs hands off each
        // interval → interleave.
        if two_d {
            Organization::Checkerboard2D
        } else {
            Organization::FineStriped1D
        }
    } else if pes_involved >= (producer_pes as u64).saturating_mul(3) / 4 {
        // Granularity ≈ total producer RF → blocked.
        if two_d {
            Organization::Blocked2D
        } else {
            Organization::Blocked1D
        }
    } else {
        // Middle ground: striped keeps locality without constraining tiles
        // as hard as checkerboard.
        Organization::FineStriped1D
    };
    let _ = deep;
    OrganizationChoice {
        organization,
        granularity_words,
        producer_rf_words: producer_rf,
        via_global_buffer: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ArchConfig {
        ArchConfig::default() // 512 B RF per PE, 1 B words
    }

    #[test]
    fn depth_one_is_sequential() {
        let c = choose_organization(&cfg(), 1, 1 << 20, 1024);
        assert_eq!(c.organization, Organization::Sequential);
        assert!(c.via_global_buffer);
    }

    #[test]
    fn oversized_granularity_goes_blocked_via_gb() {
        // granularity larger than all producer RF → GB + blocked
        let c = choose_organization(&cfg(), 2, 1 << 22, 512);
        assert_eq!(c.organization, Organization::Blocked1D);
        assert!(c.via_global_buffer);
        let c4 = choose_organization(&cfg(), 4, 1 << 22, 256);
        assert_eq!(c4.organization, Organization::Blocked2D);
    }

    #[test]
    fn fine_granularity_interleaves() {
        // one row of 64 words vs 512 PEs × 512 B RF → very fine
        let c = choose_organization(&cfg(), 2, 64, 512);
        assert_eq!(c.organization, Organization::FineStriped1D);
        assert!(!c.via_global_buffer);
        let c4 = choose_organization(&cfg(), 4, 64, 256);
        assert_eq!(c4.organization, Organization::Checkerboard2D);
    }

    #[test]
    fn near_rf_granularity_blocks() {
        // granularity ≈ total producer RF (512 PEs × 512 words = 262144)
        let c = choose_organization(&cfg(), 2, 250_000, 512);
        assert_eq!(c.organization, Organization::Blocked1D);
        assert!(!c.via_global_buffer);
    }

    #[test]
    fn middle_granularity_stripes() {
        // pes_involved ≈ half the producer
        let c = choose_organization(&cfg(), 2, 512 * 256, 512);
        assert_eq!(c.organization, Organization::FineStriped1D);
    }

    #[test]
    fn monotone_in_granularity() {
        // Coarser granularity must never pick a *finer* organization.
        fn rank(o: Organization) -> u8 {
            match o {
                Organization::Checkerboard2D => 0,
                Organization::FineStriped1D => 1,
                Organization::Blocked1D | Organization::Blocked2D => 2,
                Organization::Sequential => 3,
            }
        }
        let mut prev = 0u8;
        for g in [16u64, 1024, 65536, 262144, 1 << 21] {
            let c = choose_organization(&cfg(), 2, g, 512);
            let r = rank(c.organization);
            assert!(r >= prev, "granularity {g} got finer org");
            prev = r;
        }
    }
}
