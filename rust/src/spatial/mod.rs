//! PIPEORGAN's contribution: flexible spatial organization of pipelined
//! layers on the PE array (Sec. IV, Fig. 2).
//!
//! An [`Organization`] names a strategy (blocked 1-D/2-D, fine-striped 1-D,
//! checkerboard 2-D, sequential); [`Placement`] is a concrete PE→stage
//! assignment; [`allocate_pes`] load-balances PEs across stages by MAC
//! ratio; [`choose_organization`] is the compile-time selection rule of
//! Sec. IV-B (register file vs granularity).

mod alloc;
mod chooser;
mod placement;

pub use alloc::allocate_pes;
pub use chooser::{choose_organization, OrganizationChoice};
pub use placement::{Organization, Placement};
