//! PE→stage placements for each spatial organization strategy (Fig. 2).

/// The spatial organization strategies of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Organization {
    /// Contiguous vertical bands, one per stage (prior-work default).
    Blocked1D,
    /// Contiguous rectangular blocks in a 2-D grid (depth 4 → quadrants).
    Blocked2D,
    /// Column stripes interleaving the stages at single-column pitch,
    /// repeated in proportion to each stage's allocation ("Fine-grained-1D"
    /// / fine-striped).
    FineStriped1D,
    /// 2-D interleave: every supertile of the stage grid contains all
    /// stages ("Fine-grained-2D" / checkerboard).
    Checkerboard2D,
    /// Whole array time-multiplexed per stage (no co-residency; the
    /// op-by-op fallback).
    Sequential,
}

impl Organization {
    pub fn name(self) -> &'static str {
        match self {
            Organization::Blocked1D => "blocked_1d",
            Organization::Blocked2D => "blocked_2d",
            Organization::FineStriped1D => "fine_striped_1d",
            Organization::Checkerboard2D => "checkerboard_2d",
            Organization::Sequential => "sequential",
        }
    }

    /// Inverse of [`Organization::name`] (used by the persistent DSE cache
    /// when rehydrating segment keys).
    pub fn from_name(s: &str) -> Option<Organization> {
        match s {
            "blocked_1d" => Some(Organization::Blocked1D),
            "blocked_2d" => Some(Organization::Blocked2D),
            "fine_striped_1d" => Some(Organization::FineStriped1D),
            "checkerboard_2d" => Some(Organization::Checkerboard2D),
            "sequential" => Some(Organization::Sequential),
            _ => None,
        }
    }

    pub fn is_interleaved(self) -> bool {
        matches!(
            self,
            Organization::FineStriped1D | Organization::Checkerboard2D
        )
    }

    pub fn is_2d(self) -> bool {
        matches!(
            self,
            Organization::Blocked2D | Organization::Checkerboard2D
        )
    }
}

/// A concrete assignment of every PE to a pipeline stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub rows: usize,
    pub cols: usize,
    pub organization: Organization,
    /// Stage index per PE, row-major; `u16::MAX` = idle PE.
    assign: Vec<u16>,
    /// Number of stages.
    pub stages: usize,
}

pub const IDLE: u16 = u16::MAX;

impl Placement {
    /// Build a placement for `shares` PEs per stage (`shares.len()` stages)
    /// under the given organization. `shares` need not sum exactly to the
    /// array size for interleaved strategies (stripes repeat by ratio); for
    /// blocked strategies leftover PEs idle.
    pub fn build(
        rows: usize,
        cols: usize,
        organization: Organization,
        shares: &[usize],
    ) -> Placement {
        assert!(!shares.is_empty());
        let stages = shares.len();
        let mut assign = vec![IDLE; rows * cols];
        match organization {
            Organization::Sequential => {
                // All PEs belong to stage 0's timeslice; stage identity is
                // temporal, so mark everything stage 0.
                assign.fill(0);
            }
            Organization::Blocked1D => {
                // Vertical bands: columns proportional to shares.
                let col_counts = super::alloc::proportional(shares, cols);
                let mut c0 = 0usize;
                for (s, &w) in col_counts.iter().enumerate() {
                    for c in c0..c0 + w {
                        for r in 0..rows {
                            assign[r * cols + c] = s as u16;
                        }
                    }
                    c0 += w;
                }
            }
            Organization::FineStriped1D => {
                // Smooth weighted interleave (error diffusion): every stage
                // receives its proportional column count, spread as evenly
                // as possible — shares 1:3 → s0 s1 s1 s1 s0 s1 s1 s1, and a
                // 5-stage split of 17 columns still gives every stage ≥ 1
                // column (a plain repeating ratio pattern would not fit).
                let counts = super::alloc::proportional(shares, cols);
                let mut assigned = vec![0usize; stages];
                for c in 0..cols {
                    // stage with the largest deficit vs its quota
                    let mut best = 0usize;
                    let mut best_deficit = f64::NEG_INFINITY;
                    for (s, &count) in counts.iter().enumerate() {
                        let quota = count as f64 * (c + 1) as f64 / cols as f64;
                        let deficit = quota - assigned[s] as f64;
                        if deficit > best_deficit && assigned[s] < count {
                            best_deficit = deficit;
                            best = s;
                        }
                    }
                    assigned[best] += 1;
                    for r in 0..rows {
                        assign[r * cols + c] = best as u16;
                    }
                }
            }
            Organization::Blocked2D => {
                // Stage grid: gr × gc cells (near-square), each stage one
                // cell, cell sizes proportional to shares along the snake.
                let (gr, gc) = stage_grid(stages);
                let cell_h = rows / gr;
                let cell_w = cols / gc;
                for s in 0..stages {
                    let (br, bc) = (s / gc, s % gc);
                    let r1 = if br == gr - 1 { rows } else { (br + 1) * cell_h };
                    let c1 = if bc == gc - 1 { cols } else { (bc + 1) * cell_w };
                    for r in br * cell_h..r1 {
                        for c in bc * cell_w..c1 {
                            assign[r * cols + c] = s as u16;
                        }
                    }
                }
            }
            Organization::Checkerboard2D => {
                // Supertile of the stage grid repeated across the array:
                // every gr×gc window contains all stages.
                let (gr, gc) = stage_grid(stages);
                for r in 0..rows {
                    for c in 0..cols {
                        let s = (r % gr) * gc + (c % gc);
                        assign[r * cols + c] = if s < stages { s as u16 } else { IDLE };
                    }
                }
            }
        }
        Placement {
            rows,
            cols,
            organization,
            assign,
            stages,
        }
    }

    #[inline]
    pub fn stage_at(&self, r: usize, c: usize) -> Option<usize> {
        let v = self.assign[r * self.cols + c];
        (v != IDLE).then_some(v as usize)
    }

    /// PEs (row, col) of one stage, row-major order — the canonical tile
    /// order used by traffic derivation.
    pub fn stage_pes(&self, stage: usize) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.assign[r * self.cols + c] == stage as u16 {
                    v.push((r, c));
                }
            }
        }
        v
    }

    pub fn stage_size(&self, stage: usize) -> usize {
        self.assign
            .iter()
            .filter(|&&s| s == stage as u16)
            .count()
    }

    pub fn idle_pes(&self) -> usize {
        self.assign.iter().filter(|&&s| s == IDLE).count()
    }

    /// Every PE is assigned at most one stage; all stages non-empty.
    pub fn validate(&self) -> Result<(), String> {
        for s in 0..self.stages {
            if self.organization == Organization::Sequential && s > 0 {
                continue; // temporal stages share the array
            }
            if self.stage_size(s) == 0 {
                return Err(format!("stage {s} has no PEs"));
            }
        }
        Ok(())
    }

    /// ASCII rendering: one digit (stage index mod 10) per PE, `.` for
    /// idle — the visualization the traffic explorer prints, mirroring the
    /// colored grids of Fig. 2 / Fig. 8–11.
    pub fn render(&self) -> String {
        let mut s = String::with_capacity((self.cols + 1) * self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                match self.stage_at(r, c) {
                    Some(st) => s.push(char::from_digit((st % 10) as u32, 10).unwrap()),
                    None => s.push('.'),
                }
            }
            s.push('\n');
        }
        s
    }

    /// Mean Manhattan distance from each PE of `from_stage` to the nearest
    /// PE of `to_stage` — the locality metric that favors interleaving.
    pub fn mean_nearest_distance(&self, from_stage: usize, to_stage: usize) -> f64 {
        let from = self.stage_pes(from_stage);
        let to = self.stage_pes(to_stage);
        if from.is_empty() || to.is_empty() {
            return f64::INFINITY;
        }
        let mut total = 0f64;
        for &(r, c) in &from {
            let d = to
                .iter()
                .map(|&(tr, tc)| r.abs_diff(tr) + c.abs_diff(tc))
                .min()
                .unwrap();
            total += d as f64;
        }
        total / from.len() as f64
    }
}

/// Near-square grid for `stages` blocks: (rows, cols) with rows*cols >=
/// stages, rows <= cols.
pub fn stage_grid(stages: usize) -> (usize, usize) {
    let gr = (stages as f64).sqrt().floor().max(1.0) as usize;
    let gc = stages.div_ceil(gr);
    (gr, gc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocked_1d_bands() {
        let p = Placement::build(8, 8, Organization::Blocked1D, &[1, 1]);
        p.validate().unwrap();
        assert_eq!(p.stage_size(0), 32);
        assert_eq!(p.stage_size(1), 32);
        // contiguous: stage 0 owns cols 0..4
        for r in 0..8 {
            for c in 0..4 {
                assert_eq!(p.stage_at(r, c), Some(0));
            }
        }
    }

    #[test]
    fn blocked_1d_unequal_shares() {
        // Fig. 9b: 1×1 vs 3×3 conv → 1:9 MACs. On 8 columns ratios round to
        // 1:7 columns.
        let p = Placement::build(8, 8, Organization::Blocked1D, &[1, 9]);
        p.validate().unwrap();
        assert_eq!(p.stage_size(0), 8); // one column
        assert_eq!(p.stage_size(1), 56);
    }

    #[test]
    fn fine_striped_interleaves_columns() {
        let p = Placement::build(4, 8, Organization::FineStriped1D, &[1, 1]);
        p.validate().unwrap();
        for c in 0..8 {
            let want = (c % 2) as usize;
            assert_eq!(p.stage_at(0, c), Some(want));
        }
        // Interleaving brings the consumer adjacent: mean nearest distance 1.
        assert_eq!(p.mean_nearest_distance(0, 1), 1.0);
    }

    #[test]
    fn fine_striped_ratio_pattern() {
        let p = Placement::build(4, 8, Organization::FineStriped1D, &[2, 6]);
        // 2:6 columns spread evenly: stage 0 appears twice, never adjacent
        // to itself, stage 1 fills the rest.
        let got: Vec<_> = (0..8).map(|c| p.stage_at(0, c).unwrap()).collect();
        assert_eq!(got.iter().filter(|&&s| s == 0).count(), 2);
        assert_eq!(got.iter().filter(|&&s| s == 1).count(), 6);
        // interleaved: the two stage-0 stripes are not adjacent
        let pos: Vec<_> = (0..8).filter(|&c| got[c] == 0).collect();
        assert!(pos[1] - pos[0] >= 3, "{got:?}");
    }

    #[test]
    fn fine_striped_many_stages_narrow_array() {
        // Regression (property-test find): 5 stages on 17 columns must
        // still give every stage at least one column.
        let p = Placement::build(23, 17, Organization::FineStriped1D, &[5, 7, 3, 9, 3]);
        p.validate().unwrap();
        for s in 0..5 {
            assert!(p.stage_size(s) >= 23, "stage {s} starved");
        }
    }

    #[test]
    fn blocked_2d_quadrants_depth4() {
        let p = Placement::build(8, 8, Organization::Blocked2D, &[1, 1, 1, 1]);
        p.validate().unwrap();
        assert_eq!(p.stage_at(0, 0), Some(0));
        assert_eq!(p.stage_at(0, 7), Some(1));
        assert_eq!(p.stage_at(7, 0), Some(2));
        assert_eq!(p.stage_at(7, 7), Some(3));
        for s in 0..4 {
            assert_eq!(p.stage_size(s), 16);
        }
    }

    #[test]
    fn checkerboard_supertile_contains_all_stages() {
        let p = Placement::build(8, 8, Organization::Checkerboard2D, &[1, 1, 1, 1]);
        p.validate().unwrap();
        // 2×2 supertile: stages 0,1 / 2,3
        assert_eq!(p.stage_at(0, 0), Some(0));
        assert_eq!(p.stage_at(0, 1), Some(1));
        assert_eq!(p.stage_at(1, 0), Some(2));
        assert_eq!(p.stage_at(1, 1), Some(3));
        // perfect locality: consumer of stage 0 is adjacent
        assert_eq!(p.mean_nearest_distance(0, 1), 1.0);
        assert_eq!(p.mean_nearest_distance(0, 3), 2.0);
    }

    #[test]
    fn interleaving_beats_blocked_locality() {
        // The Fig. 2 argument: fine-grained organization places consumers
        // near producers.
        let blocked = Placement::build(16, 16, Organization::Blocked1D, &[1, 1]);
        let striped = Placement::build(16, 16, Organization::FineStriped1D, &[1, 1]);
        assert!(
            striped.mean_nearest_distance(0, 1) < blocked.mean_nearest_distance(0, 1)
        );
    }

    #[test]
    fn sequential_occupies_whole_array() {
        let p = Placement::build(4, 4, Organization::Sequential, &[1, 1, 1]);
        p.validate().unwrap();
        assert_eq!(p.stage_size(0), 16);
        assert_eq!(p.idle_pes(), 0);
    }

    #[test]
    fn render_shows_fig2_patterns() {
        let p = Placement::build(4, 4, Organization::Checkerboard2D, &[1, 1, 1, 1]);
        assert_eq!(p.render(), "0101\n2323\n0101\n2323\n");
        let b = Placement::build(2, 4, Organization::Blocked1D, &[1, 1]);
        assert_eq!(b.render(), "0011\n0011\n");
    }

    #[test]
    fn stage_grid_shapes() {
        assert_eq!(stage_grid(1), (1, 1));
        assert_eq!(stage_grid(2), (1, 2));
        assert_eq!(stage_grid(3), (1, 3));
        assert_eq!(stage_grid(4), (2, 2));
        assert_eq!(stage_grid(6), (2, 3));
        assert_eq!(stage_grid(9), (3, 3));
    }

    #[test]
    fn blocked_2d_odd_depth_non_empty() {
        let p = Placement::build(8, 9, Organization::Blocked2D, &[1, 1, 1]);
        p.validate().unwrap();
        assert_eq!(p.idle_pes(), 0);
    }

    #[test]
    fn organization_names_roundtrip() {
        for org in [
            Organization::Blocked1D,
            Organization::Blocked2D,
            Organization::FineStriped1D,
            Organization::Checkerboard2D,
            Organization::Sequential,
        ] {
            assert_eq!(Organization::from_name(org.name()), Some(org));
        }
        assert_eq!(Organization::from_name("bogus"), None);
    }
}
