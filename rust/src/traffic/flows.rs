//! Flow derivation from placements.

use crate::noc::{NodeId, Topology};
use crate::spatial::Placement;

/// Why a flow exists — used by reports and the Table II bottleneck rollup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowClass {
    /// Adjacent-stage pipeline handoff.
    Pipeline { from_stage: usize, to_stage: usize },
    /// Skip-connection handoff (non-adjacent stages).
    Skip { from_stage: usize, to_stage: usize },
}

/// One producer-PE → consumer-PE flow with its per-interval volume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flow {
    pub src: NodeId,
    pub dst: NodeId,
    pub words_per_interval: f64,
    pub class: FlowClass,
}

/// A stage-to-stage handoff of the segment (pipeline or skip edge) with the
/// words exchanged per pipeline interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageHandoff {
    pub from_stage: usize,
    pub to_stage: usize,
    pub words_per_interval: f64,
    pub is_skip: bool,
}

impl StageHandoff {
    pub fn pipeline(from: usize, to: usize, words: f64) -> Self {
        Self {
            from_stage: from,
            to_stage: to,
            words_per_interval: words,
            is_skip: false,
        }
    }

    pub fn skip(from: usize, to: usize, words: f64) -> Self {
        Self {
            from_stage: from,
            to_stage: to,
            words_per_interval: words,
            is_skip: true,
        }
    }
}

/// Derive per-PE flows for every handoff.
///
/// Producer and consumer PEs are ordered canonically (row-major within the
/// stage region — the tile order of the intermediate tensor). Producer `i`
/// sends its slice to the consumer holding the matching tile:
/// `j = ⌊i · |C| / |P|⌋`. Every producer emits `words/|P|` per interval;
/// with `|C| < |P|` several producers converge on one consumer (the Fig. 9b
/// hotspot), with `|C| > |P|` each producer fans out to the consumers of its
/// tile range.
pub fn derive_flows(
    topo: &Topology,
    placement: &Placement,
    handoffs: &[StageHandoff],
) -> Vec<Flow> {
    let mut out = Vec::new();
    for h in handoffs {
        let producers = placement.stage_pes(h.from_stage);
        let consumers = placement.stage_pes(h.to_stage);
        if producers.is_empty() || consumers.is_empty() || h.words_per_interval <= 0.0 {
            continue;
        }
        let np = producers.len();
        let nc = consumers.len();
        let class = if h.is_skip {
            FlowClass::Skip {
                from_stage: h.from_stage,
                to_stage: h.to_stage,
            }
        } else {
            FlowClass::Pipeline {
                from_stage: h.from_stage,
                to_stage: h.to_stage,
            }
        };
        if nc >= np {
            // Fan-out: producer i feeds consumers [i*nc/np, (i+1)*nc/np).
            for (i, &(pr, pc)) in producers.iter().enumerate() {
                let j0 = i * nc / np;
                let j1 = ((i + 1) * nc / np).max(j0 + 1);
                let w = h.words_per_interval / np as f64 / (j1 - j0) as f64;
                for &(cr, cc) in &consumers[j0..j1.min(nc)] {
                    push_flow(topo, &mut out, (pr, pc), (cr, cc), w, class);
                }
            }
        } else {
            // Fan-in: producer i sends to consumer ⌊i·nc/np⌋.
            let w = h.words_per_interval / np as f64;
            for (i, &(pr, pc)) in producers.iter().enumerate() {
                let j = i * nc / np;
                let (cr, cc) = consumers[j];
                push_flow(topo, &mut out, (pr, pc), (cr, cc), w, class);
            }
        }
    }
    out
}

fn push_flow(
    topo: &Topology,
    out: &mut Vec<Flow>,
    src: (usize, usize),
    dst: (usize, usize),
    words: f64,
    class: FlowClass,
) {
    let s = topo.node(src.0, src.1);
    let d = topo.node(dst.0, dst.1);
    if s == d {
        return; // same-PE handoff: stays in the register file
    }
    out.push(Flow {
        src: s,
        dst: d,
        words_per_interval: words,
        class,
    });
}

/// Total words per interval carried by a flow set (excludes same-PE
/// handoffs, which never enter the NoC).
pub fn total_words(flows: &[Flow]) -> f64 {
    flows.iter().map(|f| f.words_per_interval).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::spatial::{Organization, Placement};

    fn mesh8() -> Topology {
        Topology::new(TopologyKind::Mesh, 8, 8)
    }

    #[test]
    fn equal_blocked_pairs_producers_to_consumers() {
        let topo = mesh8();
        let p = Placement::build(8, 8, Organization::Blocked1D, &[1, 1]);
        let flows = derive_flows(&topo, &p, &[StageHandoff::pipeline(0, 1, 32.0)]);
        // 32 producers → 32 consumers, 1:1
        assert_eq!(flows.len(), 32);
        assert!((total_words(&flows) - 32.0).abs() < 1e-9);
        // every flow crosses the band boundary eastward
        for f in &flows {
            let (_, sc) = topo.coords(f.src);
            let (_, dc) = topo.coords(f.dst);
            assert!(sc < 4 && dc >= 4);
        }
    }

    #[test]
    fn striped_flows_are_single_hop() {
        let topo = mesh8();
        let p = Placement::build(8, 8, Organization::FineStriped1D, &[1, 1]);
        let flows = derive_flows(&topo, &p, &[StageHandoff::pipeline(0, 1, 32.0)]);
        for f in &flows {
            let (sr, sc) = topo.coords(f.src);
            let (dr, dc) = topo.coords(f.dst);
            let hops = sr.abs_diff(dr) + sc.abs_diff(dc);
            assert!(hops <= 2, "striped flow spans {hops} hops");
        }
    }

    #[test]
    fn unequal_allocation_fans_in() {
        let topo = mesh8();
        // 56 producers, 8 consumers (7:1) — Fig. 9b inverted direction.
        let p = Placement::build(8, 8, Organization::Blocked1D, &[7, 1]);
        let flows = derive_flows(&topo, &p, &[StageHandoff::pipeline(0, 1, 56.0)]);
        assert_eq!(flows.len(), 56);
        // each consumer receives 7 flows
        let mut per_dst = std::collections::HashMap::new();
        for f in &flows {
            *per_dst.entry(f.dst).or_insert(0usize) += 1;
        }
        assert!(per_dst.values().all(|&n| n == 7));
    }

    #[test]
    fn fan_out_conserves_words() {
        let topo = mesh8();
        let p = Placement::build(8, 8, Organization::Blocked1D, &[1, 7]);
        let flows = derive_flows(&topo, &p, &[StageHandoff::pipeline(0, 1, 10.0)]);
        assert!((total_words(&flows) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn skip_flows_are_classified() {
        let topo = mesh8();
        let p = Placement::build(8, 8, Organization::Blocked1D, &[1, 1, 1, 1]);
        let flows = derive_flows(
            &topo,
            &p,
            &[
                StageHandoff::pipeline(0, 1, 8.0),
                StageHandoff::skip(1, 3, 8.0),
            ],
        );
        let skips: Vec<_> = flows
            .iter()
            .filter(|f| matches!(f.class, FlowClass::Skip { .. }))
            .collect();
        assert!(!skips.is_empty());
        for f in skips {
            let (_, sc) = topo.coords(f.src);
            let (_, dc) = topo.coords(f.dst);
            assert!(sc < 4 && dc >= 6); // stage 1 band → stage 3 band
        }
    }

    #[test]
    fn same_pe_handoffs_do_not_enter_noc() {
        let topo = mesh8();
        let p = Placement::build(8, 8, Organization::Sequential, &[1, 1]);
        // Sequential: both "stages" own the same PEs → all handoffs are
        // same-PE... stage_pes(1) is empty under Sequential (all marked 0),
        // so no flows at all.
        let flows = derive_flows(&topo, &p, &[StageHandoff::pipeline(0, 1, 8.0)]);
        assert!(flows.is_empty());
    }

    #[test]
    fn property_words_conserved_across_shapes() {
        crate::util::proptest_lite::run(100, |rng| {
            let topo = mesh8();
            let a = rng.gen_usize(1, 7);
            let b = rng.gen_usize(1, 9 - a);
            let p = Placement::build(8, 8, Organization::Blocked1D, &[a, b]);
            let words = rng.gen_usize(1, 1000) as f64;
            let flows = derive_flows(&topo, &p, &[StageHandoff::pipeline(0, 1, words)]);
            let tot = total_words(&flows);
            crate::prop_assert!(
                (tot - words).abs() < 1e-6 * words.max(1.0),
                "words {words} != {tot}"
            );
            Ok(())
        });
    }
}
