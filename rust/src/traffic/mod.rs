//! Traffic derivation (Sec. IV-C): turn a spatial placement plus the
//! segment's pipelined handoffs into per-interval NoC flows, including skip
//! connection traffic and the hotspots caused by unequal PE allocation.

mod flows;
pub mod scenarios;

pub use flows::{derive_flows, total_words, Flow, FlowClass, StageHandoff};
