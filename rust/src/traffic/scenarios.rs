//! The design-time traffic-analysis scenario library: the exact cases the
//! paper draws in Fig. 8–12, expressed as (placement, handoffs,
//! compute-interval) triples ready for channel-load analysis.

use crate::spatial::{Organization, Placement};

use super::flows::StageHandoff;

/// A named traffic scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub placement: Placement,
    pub handoffs: Vec<StageHandoff>,
    /// Compute cycles per pipeline interval (the temporal-reduction time
    /// Fig. 8 compares the hop time against).
    pub compute_interval: f64,
}

/// Words exchanged per interval in the canonical scenarios: one output
/// element per producer PE per interval (fine-grained row pipelining on an
/// array whose row holds the tile).
fn words_per_interval(producer_pes: usize) -> f64 {
    producer_pes as f64
}

/// Fig. 8 left: depth-2, equal allocation, blocked 1-D, fine-grained
/// pipelining.
pub fn fig8_depth2_blocked(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::Blocked1D, &[1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig8_depth2_blocked1d",
        placement,
        handoffs: vec![StageHandoff::pipeline(0, 1, w)],
        compute_interval: 2.0,
    }
}

/// Fig. 8 right: depth-4, equal allocation, blocked 1-D.
pub fn fig8_depth4_blocked(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::Blocked1D, &[1, 1, 1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig8_depth4_blocked1d",
        placement,
        handoffs: vec![
            StageHandoff::pipeline(0, 1, w),
            StageHandoff::pipeline(1, 2, w),
            StageHandoff::pipeline(2, 3, w),
        ],
        compute_interval: 2.0,
    }
}

/// Fig. 9a: depth-2 blocked with a residual skip adding traffic on the same
/// boundary (ResNet residual block: the skip source is the segment input
/// forwarded alongside).
pub fn fig9a_skip_blocked(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::Blocked1D, &[1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig9a_skip_blocked1d",
        placement,
        handoffs: vec![
            StageHandoff::pipeline(0, 1, w),
            // skip connection doubles the boundary traffic
            StageHandoff::skip(0, 1, w),
        ],
        compute_interval: 2.0,
    }
}

/// Fig. 9b: unequal PE allocation (1×1 vs 3×3 conv → 1:9 MACs) on blocked
/// 1-D — the boundary hotspot case.
pub fn fig9b_unequal_blocked(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::Blocked1D, &[1, 9]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig9b_unequal_blocked1d",
        placement,
        handoffs: vec![StageHandoff::pipeline(0, 1, w)],
        compute_interval: 2.0,
    }
}

/// Fig. 10: the same three cases on fine-striped 1-D interleaving
/// (congestion-free counterparts).
pub fn fig10_striped(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::FineStriped1D, &[1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig10_depth2_striped",
        placement,
        handoffs: vec![StageHandoff::pipeline(0, 1, w)],
        compute_interval: 2.0,
    }
}

pub fn fig10_striped_skip(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::FineStriped1D, &[1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig10_skip_striped",
        placement,
        handoffs: vec![
            StageHandoff::pipeline(0, 1, w),
            StageHandoff::skip(0, 1, w),
        ],
        compute_interval: 2.0,
    }
}

pub fn fig10_striped_unequal(rows: usize, cols: usize) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::FineStriped1D, &[1, 9]);
    let w = words_per_interval(placement.stage_size(0));
    Scenario {
        name: "fig10_unequal_striped",
        placement,
        handoffs: vec![StageHandoff::pipeline(0, 1, w)],
        compute_interval: 2.0,
    }
}

/// Fig. 11 left: depth-4 blocked 2-D (quadrants), pipeline snake
/// east→south→west, with the L2→L4 skip (stage 1→3) traversing two path
/// sets.
pub fn fig11_blocked2d(rows: usize, cols: usize, with_skip: bool) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::Blocked2D, &[1, 1, 1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    let mut handoffs = vec![
        StageHandoff::pipeline(0, 1, w),
        StageHandoff::pipeline(1, 2, w),
        StageHandoff::pipeline(2, 3, w),
    ];
    if with_skip {
        handoffs.push(StageHandoff::skip(1, 3, w));
    }
    Scenario {
        name: if with_skip {
            "fig11_blocked2d_skip"
        } else {
            "fig11_blocked2d"
        },
        placement,
        handoffs,
        compute_interval: 2.0,
    }
}

/// Fig. 11 right: depth-4 checkerboard 2-D interleaving.
pub fn fig11_checkerboard(rows: usize, cols: usize, with_skip: bool) -> Scenario {
    let placement = Placement::build(rows, cols, Organization::Checkerboard2D, &[1, 1, 1, 1]);
    let w = words_per_interval(placement.stage_size(0));
    let mut handoffs = vec![
        StageHandoff::pipeline(0, 1, w),
        StageHandoff::pipeline(1, 2, w),
        StageHandoff::pipeline(2, 3, w),
    ];
    if with_skip {
        handoffs.push(StageHandoff::skip(1, 3, w));
    }
    Scenario {
        name: if with_skip {
            "fig11_checkerboard_skip"
        } else {
            "fig11_checkerboard"
        },
        placement,
        handoffs,
        compute_interval: 2.0,
    }
}

/// All scenarios at the paper's array size, for sweeps and Table II.
pub fn all(rows: usize, cols: usize) -> Vec<Scenario> {
    vec![
        fig8_depth2_blocked(rows, cols),
        fig8_depth4_blocked(rows, cols),
        fig9a_skip_blocked(rows, cols),
        fig9b_unequal_blocked(rows, cols),
        fig10_striped(rows, cols),
        fig10_striped_skip(rows, cols),
        fig10_striped_unequal(rows, cols),
        fig11_blocked2d(rows, cols, false),
        fig11_blocked2d(rows, cols, true),
        fig11_checkerboard(rows, cols, false),
        fig11_checkerboard(rows, cols, true),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_build_at_paper_size() {
        let all = all(32, 32);
        assert_eq!(all.len(), 11);
        for s in &all {
            s.placement.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
            assert!(!s.handoffs.is_empty());
            assert!(s.compute_interval > 0.0);
        }
    }

    #[test]
    fn names_unique() {
        let names: Vec<_> = all(16, 16).iter().map(|s| s.name).collect();
        let set: std::collections::BTreeSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len());
    }
}
