//! Minimal JSON value + serializer (serde substitute, output-side only).
//!
//! Report emitters write machine-readable results (one file per reproduced
//! figure/table) so external tooling can plot them; this module provides the
//! small typed value tree they serialize through.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Compact (no-whitespace) serialization; `Json::to_string()` comes from
/// this impl via the blanket `ToString`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

impl Json {
    /// Parse a JSON document (strict enough for the AOT manifest; rejects
    /// trailing garbage).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    // ---- typed accessors (used by manifest readers) ----------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err("unexpected end of input".into());
    };
    match c {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key must be string, got {other:?}")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            loop {
                let Some(&c) = b.get(*pos) else {
                    return Err("unterminated string".into());
                };
                *pos += 1;
                match c {
                    b'"' => return Ok(Json::Str(s)),
                    b'\\' => {
                        let Some(&e) = b.get(*pos) else {
                            return Err("unterminated escape".into());
                        };
                        *pos += 1;
                        match e {
                            b'"' => s.push('"'),
                            b'\\' => s.push('\\'),
                            b'/' => s.push('/'),
                            b'n' => s.push('\n'),
                            b't' => s.push('\t'),
                            b'r' => s.push('\r'),
                            b'b' => s.push('\u{8}'),
                            b'f' => s.push('\u{c}'),
                            b'u' => {
                                if *pos + 4 > b.len() {
                                    return Err("bad \\u escape".into());
                                }
                                let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                                    .map_err(|_| "bad \\u escape")?;
                                let code = u32::from_str_radix(hex, 16)
                                    .map_err(|_| "bad \\u escape")?;
                                s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                                *pos += 4;
                            }
                            _ => return Err(format!("bad escape \\{}", e as char)),
                        }
                    }
                    _ => {
                        // copy raw UTF-8 bytes through
                        let start = *pos - 1;
                        let mut end = *pos;
                        while end < b.len() && b[end] != b'"' && b[end] != b'\\' {
                            end += 1;
                        }
                        s.push_str(
                            std::str::from_utf8(&b[start..end]).map_err(|e| e.to_string())?,
                        );
                        *pos = end;
                    }
                }
            }
        }
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number `{s}` at byte {start}"))
        }
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}"))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3u64).to_string(), "3");
        assert_eq!(Json::from(1.5).to_string(), "1.5");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nested_object_is_sorted_and_stable() {
        let mut o = Json::obj();
        o.set("b", 2u64).set("a", vec![1u64, 2]);
        assert_eq!(o.to_string(), r#"{"a":[1,2],"b":2}"#);
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let mut o = Json::obj();
        o.set("xs", vec![1.0, 2.5]);
        let p = o.to_pretty();
        assert!(p.contains("\n  \"xs\": [\n"), "{p}");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::obj()));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_serialize_parse() {
        let mut o = Json::obj();
        o.set("name", "pipeorgan")
            .set("n", 42u64)
            .set("xs", vec![1.5, 2.5])
            .set("flag", true);
        let parsed = Json::parse(&o.to_pretty()).unwrap();
        assert_eq!(parsed, o);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }
}
