//! Small self-contained utilities.
//!
//! This environment has no network access, so several crates a production
//! codebase would normally pull in (rand, serde, criterion, proptest) are
//! replaced by the minimal local implementations in this module. See
//! DESIGN.md §2 for the substitution table.

pub mod json;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
pub mod table;

/// Integer ceiling division for positive operands.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// Least common multiple (u64, panics on zero operands).
pub fn lcm(a: u64, b: u64) -> u64 {
    assert!(a > 0 && b > 0, "lcm of zero");
    a / gcd(a, b) * b
}

/// Greatest common divisor (Euclid).
pub fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_rounds_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 100), 1);
        assert_eq!(ceil_div(0, 7), 0);
    }

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(5, 7), 35);
        assert_eq!(lcm(8, 8), 8);
    }

    #[test]
    #[should_panic]
    fn lcm_zero_panics() {
        lcm(0, 3);
    }
}
