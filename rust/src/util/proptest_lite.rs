//! Tiny property-based testing harness (proptest substitute — no network,
//! so the real crate is unavailable; see DESIGN.md §2).
//!
//! Usage:
//! ```ignore
//! proptest_lite::run(256, |rng| {
//!     let n = rng.gen_usize(1, 64);
//!     // ... generate a case, assert invariants; return Err(msg) to fail.
//!     Ok(())
//! });
//! ```
//! Failures report the seed of the failing case so it can be replayed with
//! [`replay`]. No shrinking — generators are kept small enough that the raw
//! failing case is readable.

use super::rng::SplitMix64;

/// Run `cases` random test cases. Each case gets an independent RNG seeded
/// from a fixed master seed, so the whole suite is deterministic.
pub fn run<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    run_seeded(0xC0FFEE, cases, &mut prop)
}

/// Like [`run`] with an explicit master seed.
pub fn run_seeded<F>(master_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    let mut master = SplitMix64::new(master_seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut rng = SplitMix64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, prop: &mut F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    let mut rng = SplitMix64::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed property failure (seed {case_seed:#x}): {msg}");
    }
}

/// Assertion helper returning `Err` instead of panicking, for use inside
/// properties so the harness can attach the replay seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        run(64, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        run(16, |rng| {
            let x = rng.gen_range(10);
            if x >= 5 {
                return Err(format!("x too big: {x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut trace_a = Vec::new();
        run(8, |rng| {
            trace_a.push(rng.next_u64());
            Ok(())
        });
        let mut trace_b = Vec::new();
        run(8, |rng| {
            trace_b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(trace_a, trace_b);
    }
}
