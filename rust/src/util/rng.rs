//! Deterministic PRNG (SplitMix64) — substitute for the `rand` crate.
//!
//! Everything in the simulator that needs randomness (workload perturbation,
//! property-test case generation, tie-breaking) goes through this so runs are
//! reproducible from a seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; the reference
/// generator recommended for seeding xoshiro. 64 bits of state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero. Uses Lemire's
    /// multiply-shift rejection-free approximation (fine for simulation use).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be > 0");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open); panics if `lo >= hi`.
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Pick an element of a nonempty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_usize(0, xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_f64_roughly_uniform() {
        let mut r = SplitMix64::new(123);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
