//! Summary statistics used by the benchmark harnesses and result rollups.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; panics if any element is non-positive. This is the
/// aggregation the paper uses for its headline 1.95× / 31% numbers.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a copy.
///
/// Sorts per call; when several percentiles are read off the same samples,
/// build a [`Histogram`] once instead.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    Histogram::from_samples(xs).percentile(p)
}

/// Sort-once sample set: sorts at construction, then serves any number of
/// nearest-rank percentile reads without re-sorting. This is the shared
/// percentile path for `serve::metrics` (p50/p95/p99 per task) and the
/// `obs::counters` histogram cells, deduplicating what used to be one sort
/// per percentile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    sorted: Vec<f64>,
}

impl Histogram {
    /// Build from unsorted samples (one sort, NaN-free input assumed).
    pub fn from_samples(xs: &[f64]) -> Self {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { sorted }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Nearest-rank percentile (0..=100); 0.0 for an empty histogram, so
    /// callers reporting tasks that never completed a request need no guard.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
        if self.sorted.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.sorted.len() - 1) as f64).round() as usize;
        self.sorted[rank]
    }

    /// Arithmetic mean; 0.0 for empty.
    pub fn mean(&self) -> f64 {
        mean(&self.sorted)
    }

    /// Smallest sample; 0.0 for empty.
    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(0.0)
    }

    /// Largest sample; 0.0 for empty.
    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Timing summary for the custom bench harness (criterion substitute).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    pub fn from_ns(samples: &[f64]) -> Self {
        Self {
            n: samples.len(),
            mean_ns: mean(samples),
            stddev_ns: stddev(samples),
            min_ns: min(samples),
            p50_ns: percentile(samples, 50.0),
            p95_ns: percentile(samples, 95.0),
            max_ns: max(samples),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn h(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        write!(
            f,
            "n={} mean={} ±{} min={} p50={} p95={} max={}",
            self.n,
            h(self.mean_ns),
            h(self.stddev_ns),
            h(self.min_ns),
            h(self.p50_ns),
            h(self.p95_ns),
            h(self.max_ns)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 8.0, 4.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn summary_display_units() {
        let s = Summary::from_ns(&[1500.0, 1500.0]);
        let txt = format!("{s}");
        assert!(txt.contains("µs"), "{txt}");
    }

    #[test]
    fn histogram_matches_percentile_fn() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let h = Histogram::from_samples(&xs);
        for p in [0.0, 25.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), percentile(&xs, p), "p{p}");
        }
        assert_eq!(h.len(), 5);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 5.0);
        assert_eq!(h.mean(), 3.0);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::from_samples(&[]);
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    #[should_panic]
    fn histogram_percentile_range_checked() {
        Histogram::from_samples(&[1.0]).percentile(101.0);
    }
}
