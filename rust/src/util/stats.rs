//! Summary statistics used by the benchmark harnesses and result rollups.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; panics if any element is non-positive. This is the
/// aggregation the paper uses for its headline 1.95× / 31% numbers.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank]
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Timing summary for the custom bench harness (criterion substitute).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    pub fn from_ns(samples: &[f64]) -> Self {
        Self {
            n: samples.len(),
            mean_ns: mean(samples),
            stddev_ns: stddev(samples),
            min_ns: min(samples),
            p50_ns: percentile(samples, 50.0),
            p95_ns: percentile(samples, 95.0),
            max_ns: max(samples),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn h(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        write!(
            f,
            "n={} mean={} ±{} min={} p50={} p95={} max={}",
            self.n,
            h(self.mean_ns),
            h(self.stddev_ns),
            h(self.min_ns),
            h(self.p50_ns),
            h(self.p95_ns),
            h(self.max_ns)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 8.0, 4.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn summary_display_units() {
        let s = Summary::from_ns(&[1500.0, 1500.0]);
        let txt = format!("{s}");
        assert!(txt.contains("µs"), "{txt}");
    }
}
