//! Plain-text / markdown / CSV table formatting for figure and table
//! emitters. Every reproduced paper artifact is printed as an aligned
//! markdown table on stdout and written as CSV next to it.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from `Display` items.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned markdown rendering.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut s = String::new();
        if !self.title.is_empty() {
            s.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        sep.push('\n');
        s.push_str(&sep);
        for r in &self.rows {
            s.push_str(&fmt_row(r));
        }
        s
    }

    /// CSV rendering (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        fn esc(c: &str) -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        }
        let mut s = String::new();
        s.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }
}

/// Format a f64 with a sensible number of significant digits for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | long_header |"), "{md}");
        assert!(md.contains("| 1 | 2           |"), "{md}");
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new("", &["x"]);
        t.row(&["a,b".into()]);
        assert_eq!(t.to_csv(), "x\n\"a,b\"\n");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.6), "1235");
        assert_eq!(fnum(12.34), "12.3");
        assert_eq!(fnum(1.2345), "1.234");
        assert!(fnum(0.0001).contains('e'));
    }
}
