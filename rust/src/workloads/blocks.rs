//! Reusable block builders: residual, bottleneck, dense (DenseNet/RITNet),
//! inverted-residual (MobileNet/FBNet), and TCN blocks.

use crate::ir::{Layer, LayerId, ModelGraph, Op};

/// Basic ResNet residual block: two 3×3 convs + identity skip (+ optional
/// 1×1 projection when channels/stride change). Returns the output layer id.
pub fn residual_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    n: usize,
    h: usize,
    w: usize,
    c_in: usize,
    c_out: usize,
    stride: usize,
) -> LayerId {
    let c1 = g.add_layer(
        Layer::new(
            format!("{tag}.conv1"),
            Op::conv2d(n, h, w, c_in, c_out, 3, 3, stride, 1),
        ),
        &[input],
    );
    let (oh, ow) = (h / stride, w / stride);
    let c2 = g.add_layer(
        Layer::new(
            format!("{tag}.conv2"),
            Op::conv2d(n, oh, ow, c_out, c_out, 3, 3, 1, 1),
        ),
        &[c1],
    );
    let skip_src = if c_in != c_out || stride != 1 {
        // Projection shortcut (1×1, stride) — the unequal-allocation case of
        // Fig. 9b arises exactly from this 1×1-vs-3×3 mix.
        g.add_layer(
            Layer::new(
                format!("{tag}.proj"),
                Op::conv2d(n, h, w, c_in, c_out, 1, 1, stride, 0),
            ),
            &[input],
        )
    } else {
        input
    };
    let add = g.add_layer(
        Layer::new(format!("{tag}.add"), Op::eltwise_add(n, oh, ow, c_out)),
        &[c2],
    );
    g.add_edge(skip_src, add);
    add
}

/// ResNet bottleneck block: 1×1 reduce → 3×3 → 1×1 expand + skip.
#[allow(clippy::too_many_arguments)]
pub fn bottleneck_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    n: usize,
    h: usize,
    w: usize,
    c_in: usize,
    c_mid: usize,
    c_out: usize,
    stride: usize,
) -> LayerId {
    let c1 = g.add_layer(
        Layer::new(
            format!("{tag}.reduce"),
            Op::conv2d(n, h, w, c_in, c_mid, 1, 1, 1, 0),
        ),
        &[input],
    );
    let c2 = g.add_layer(
        Layer::new(
            format!("{tag}.conv3x3"),
            Op::conv2d(n, h, w, c_mid, c_mid, 3, 3, stride, 1),
        ),
        &[c1],
    );
    let (oh, ow) = (h / stride, w / stride);
    let c3 = g.add_layer(
        Layer::new(
            format!("{tag}.expand"),
            Op::conv2d(n, oh, ow, c_mid, c_out, 1, 1, 1, 0),
        ),
        &[c2],
    );
    let skip_src = if c_in != c_out || stride != 1 {
        g.add_layer(
            Layer::new(
                format!("{tag}.proj"),
                Op::conv2d(n, h, w, c_in, c_out, 1, 1, stride, 0),
            ),
            &[input],
        )
    } else {
        input
    };
    let add = g.add_layer(
        Layer::new(format!("{tag}.add"), Op::eltwise_add(n, oh, ow, c_out)),
        &[c3],
    );
    g.add_edge(skip_src, add);
    add
}

/// DenseNet-style block as used by RITNet: `depth` convs where conv *i*
/// additionally receives skip edges from every earlier conv in the block —
/// the densest skip pattern in XR-bench (Fig. 6, eye segmentation). The last
/// layer combines all previous activations.
pub fn dense_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    depth: usize,
) -> LayerId {
    assert!(depth >= 2);
    let mut produced: Vec<LayerId> = Vec::with_capacity(depth + 1);
    let first = g.add_layer(
        Layer::new(format!("{tag}.conv0"), Op::conv2d(n, h, w, c, c, 3, 3, 1, 1)),
        &[input],
    );
    produced.push(first);
    for i in 1..depth {
        let conv = g.add_layer(
            Layer::new(
                format!("{tag}.conv{i}"),
                Op::conv2d(n, h, w, c, c, 3, 3, 1, 1),
            ),
            &[*produced.last().unwrap()],
        );
        // Dense skips: every earlier conv in the block feeds this one.
        for &p in &produced[..produced.len() - 1] {
            g.add_edge(p, conv);
        }
        produced.push(conv);
    }
    // Final combine of all block outputs (DenseNet concat modeled as a
    // multi-input elementwise combine with the same fan-in volume).
    let add = g.add_layer(
        Layer::new(
            format!("{tag}.combine"),
            Op::eltwise_add_n(n, h, w, c, produced.len()),
        ),
        &[*produced.last().unwrap()],
    );
    for &p in &produced[..produced.len() - 1] {
        g.add_edge(p, add);
    }
    add
}

/// MobileNet/FBNet inverted-residual block: 1×1 expand → 3×3 depthwise →
/// 1×1 project, with skip when shapes allow. DWCONV is the memory-bound,
/// high-A/W layer the paper calls out in depth estimation.
#[allow(clippy::too_many_arguments)]
pub fn inverted_residual_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    n: usize,
    h: usize,
    w: usize,
    c_in: usize,
    expand: usize,
    c_out: usize,
    stride: usize,
) -> LayerId {
    let c_mid = c_in * expand;
    let e = g.add_layer(
        Layer::new(
            format!("{tag}.expand"),
            Op::conv2d(n, h, w, c_in, c_mid, 1, 1, 1, 0),
        ),
        &[input],
    );
    let dw = g.add_layer(
        Layer::new(format!("{tag}.dw"), Op::dwconv2d(n, h, w, c_mid, 3, stride)),
        &[e],
    );
    let (oh, ow) = (h / stride, w / stride);
    let p = g.add_layer(
        Layer::new(
            format!("{tag}.project"),
            Op::conv2d(n, oh, ow, c_mid, c_out, 1, 1, 1, 0),
        ),
        &[dw],
    );
    if c_in == c_out && stride == 1 {
        let add = g.add_layer(
            Layer::new(format!("{tag}.add"), Op::eltwise_add(n, oh, ow, c_out)),
            &[p],
        );
        g.add_edge(input, add);
        add
    } else {
        p
    }
}

/// RITNet-style UpBlock: upsample ×2 then two convs (the activation-heavy
/// segment Fig. 2 / Fig. 11 analyze).
pub fn up_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
) -> LayerId {
    let up = g.add_layer(
        Layer::new(format!("{tag}.up"), Op::upsample(n, h, w, c, 2)),
        &[input],
    );
    let (uh, uw) = (h * 2, w * 2);
    let c1 = g.add_layer(
        Layer::new(
            format!("{tag}.conv0"),
            Op::conv2d(n, uh, uw, c, c, 3, 3, 1, 1),
        ),
        &[up],
    );
    g.add_layer(
        Layer::new(
            format!("{tag}.conv1"),
            Op::conv2d(n, uh, uw, c, c, 3, 3, 1, 1),
        ),
        &[c1],
    )
}

/// Temporal-conv (TCN) block: two dilated 1-D convolutions over `frames`
/// timesteps with `c` channels + residual. Modeled as H=frames, W=1 convs
/// with large channel counts → weight-heavy.
pub fn tcn_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    frames: usize,
    c_in: usize,
    c_out: usize,
    kernel: usize,
) -> LayerId {
    let c1 = g.add_layer(
        Layer::new(
            format!("{tag}.tconv0"),
            Op::conv2d(1, frames, 1, c_in, c_out, kernel, 1, 1, kernel / 2),
        ),
        &[input],
    );
    let c2 = g.add_layer(
        Layer::new(
            format!("{tag}.tconv1"),
            Op::conv2d(1, frames, 1, c_out, c_out, kernel, 1, 1, kernel / 2),
        ),
        &[c1],
    );
    let skip_src = if c_in != c_out {
        g.add_layer(
            Layer::new(
                format!("{tag}.proj"),
                Op::conv2d(1, frames, 1, c_in, c_out, 1, 1, 1, 0),
            ),
            &[input],
        )
    } else {
        input
    };
    let add = g.add_layer(
        Layer::new(format!("{tag}.add"), Op::eltwise_add(1, frames, 1, c_out)),
        &[c2],
    );
    g.add_edge(skip_src, add);
    add
}

/// Transformer-ish FFN pair of GEMMs (Emformer-style acoustic layers):
/// `[seq, d] × [d, 4d]` then `[seq, 4d] × [4d, d]`, residual around.
pub fn ffn_block(
    g: &mut ModelGraph,
    input: LayerId,
    tag: &str,
    seq: usize,
    d: usize,
) -> LayerId {
    let up = g.add_layer(
        Layer::new(format!("{tag}.ffn_up"), Op::gemm(seq, d, 4 * d)),
        &[input],
    );
    let down = g.add_layer(
        Layer::new(format!("{tag}.ffn_down"), Op::gemm(seq, 4 * d, d)),
        &[up],
    );
    let add = g.add_layer(
        Layer::new(format!("{tag}.add"), Op::eltwise_add(1, seq, 1, d)),
        &[down],
    );
    g.add_edge(input, add);
    add
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_block_shape_and_skip() {
        let mut g = ModelGraph::new("t");
        let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 32, 32, 3, 16, 3, 3, 1, 1)));
        let out = residual_block(&mut g, stem, "b0", 1, 32, 32, 16, 16, 1);
        assert!(g.validate().is_ok());
        // identity skip: one skip edge, no projection layer
        assert_eq!(g.skip_edges().len(), 1);
        assert_eq!(g.layer(out).output_act_words(), 32 * 32 * 16);
    }

    #[test]
    fn residual_block_projection_on_stride() {
        let mut g = ModelGraph::new("t");
        let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 32, 32, 3, 16, 3, 3, 1, 1)));
        let out = residual_block(&mut g, stem, "b0", 1, 32, 32, 16, 32, 2);
        assert!(g.validate().is_ok());
        assert!(g.layers().iter().any(|l| l.name == "b0.proj"));
        assert_eq!(g.layer(out).output_act_words(), 16 * 16 * 32);
    }

    #[test]
    fn dense_block_skip_count() {
        let mut g = ModelGraph::new("t");
        let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 16, 16, 8, 8, 3, 3, 1, 1)));
        let _ = dense_block(&mut g, stem, "d", 1, 16, 16, 8, 4);
        assert!(g.validate().is_ok());
        // conv_i gets skips from conv_0..i-1 (i>=2... conv1 gets 0 extra
        // since its only non-chain pred is conv0? No: conv1's chain pred is
        // conv0, extras none; conv2 gets 1; conv3 gets 2; combine gets 3.
        let expect = 1 + 2 + 3;
        assert_eq!(g.skip_edges().len(), expect);
    }

    #[test]
    fn inverted_residual_dwconv_aw_dominates() {
        let mut g = ModelGraph::new("t");
        let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 56, 56, 3, 24, 3, 3, 1, 1)));
        let _ = inverted_residual_block(&mut g, stem, "ir", 1, 56, 56, 24, 6, 24, 1);
        let dw = g.layers().iter().find(|l| l.name == "ir.dw").unwrap();
        assert!(dw.aw_ratio() > 300.0, "dw A/W = {}", dw.aw_ratio());
        assert_eq!(g.skip_edges().len(), 1);
    }

    #[test]
    fn ffn_block_weight_heavy_at_small_seq() {
        let mut g = ModelGraph::new("t");
        let stem = g.add_root(Layer::new("in", Op::gemm(8, 512, 512)));
        let _ = ffn_block(&mut g, stem, "ffn", 8, 512);
        let up = g.layers().iter().find(|l| l.name == "ffn.ffn_up").unwrap();
        assert!(up.aw_ratio() < 0.1, "ffn A/W = {}", up.aw_ratio());
    }

    #[test]
    fn up_block_quadruples_output() {
        let mut g = ModelGraph::new("t");
        let stem = g.add_root(Layer::new("in", Op::conv2d(1, 8, 8, 4, 4, 3, 3, 1, 1)));
        let out = up_block(&mut g, stem, "u", 1, 8, 8, 4);
        assert_eq!(g.layer(out).output_act_words(), 16 * 16 * 4);
    }
}
