//! XR-bench-like workload zoo (Sec. V-B substitution — see DESIGN.md §2).
//!
//! The paper evaluates on XR-bench CNN tasks; exact per-layer dimensions are
//! not published, so each task here is rebuilt from the *cited public model
//! paper* (RITNet, MiDaS, res8/res15 keyword nets, TCN, 3-D hand pose,
//! Faster-R-CNN/PlaneRCNN-style detection, Emformer-style acoustic model).
//! What matters for reproduction is preserved by construction:
//!   - the ~6-orders-of-magnitude A/W-ratio spread (Fig. 5),
//!   - skip-connection density and reuse-distance diversity (Fig. 6),
//!   - presence of complex layers (RPN / ROIAlign) that cut pipelines,
//!   - DWCONV-heavy memory-bound decoder regions (depth estimation).

pub mod blocks;
pub mod synthetic;
mod tasks;

pub use tasks::{
    action_segmentation, depth_estimation, eye_segmentation, gaze_estimation, hand_tracking,
    keyword_detection, object_detection, plane_detection, world_locking,
};

use crate::ir::ModelGraph;

/// All XR-bench-like tasks, in the order the paper's figures list them.
pub fn all_tasks() -> Vec<ModelGraph> {
    vec![
        eye_segmentation(),
        gaze_estimation(),
        depth_estimation(),
        hand_tracking(),
        keyword_detection(),
        action_segmentation(),
        object_detection(),
        plane_detection(),
        world_locking(),
    ]
}

/// Look a task up by its graph name.
pub fn task_by_name(name: &str) -> Option<ModelGraph> {
    all_tasks().into_iter().find(|g| g.name == name)
}

pub fn task_names() -> Vec<String> {
    all_tasks().into_iter().map(|g| g.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::skips::SkipProfile;

    #[test]
    fn all_tasks_validate() {
        for g in all_tasks() {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert!(g.num_layers() >= 8, "{} too small", g.name);
            assert!(g.total_macs() > 0, "{} has no compute", g.name);
        }
    }

    #[test]
    fn task_lookup_by_name() {
        for name in task_names() {
            assert!(task_by_name(&name).is_some(), "missing {name}");
        }
        assert!(task_by_name("nope").is_none());
    }

    #[test]
    fn aw_ratio_spread_spans_many_orders_of_magnitude() {
        // Fig. 5: ratios roughly span 1e-3 .. 1e3.
        let mut lo = f64::INFINITY;
        let mut hi: f64 = 0.0;
        for g in all_tasks() {
            for l in g.layers() {
                if l.weight_words() == 0 {
                    continue; // weight-free ops are off-scale by definition
                }
                let r = l.aw_ratio();
                lo = lo.min(r);
                hi = hi.max(r);
            }
        }
        assert!(lo < 1e-2, "min A/W ratio {lo} not weight-dominant enough");
        assert!(hi > 1e2, "max A/W ratio {hi} not activation-dominant enough");
        assert!(hi / lo > 1e5, "spread {:.1e} below ~6 orders", hi / lo);
    }

    #[test]
    fn skip_structures_are_diverse() {
        // RITNet-like eye segmentation: dense skips, several distances.
        let eye = SkipProfile::of(&eye_segmentation());
        assert!(eye.density > 0.3, "eye density {}", eye.density);
        assert!(eye.max_distance >= 3);
        // MiDaS-like depth estimation: sparse but long-distance skips.
        let depth = SkipProfile::of(&depth_estimation());
        assert!(depth.density < eye.density);
        assert!(depth.max_distance >= 8, "depth max {}", depth.max_distance);
        assert!(eye.num_skips() > depth.num_skips() * 3);
        // Keyword detection: regular residual (fixed-distance) skips.
        let kw = SkipProfile::of(&keyword_detection());
        assert!(kw.num_skips() >= 3);
        assert!(kw.edges.iter().all(|&(_, _, d)| d == 3));
    }

    #[test]
    fn detection_tasks_contain_complex_layers() {
        for g in [object_detection(), plane_detection()] {
            assert!(
                g.layers().iter().any(|l| l.is_complex()),
                "{} lacks RPN/ROIAlign",
                g.name
            );
        }
    }

    #[test]
    fn weight_heavy_tasks_are_weight_heavy() {
        use crate::util::stats::geomean;
        // Action segmentation / hand tracking should skew weight-heavy
        // (paper: "Action segmentation and hand tracking are mostly weight
        // heavy ... do not favor pipelining").
        for g in [action_segmentation(), hand_tracking()] {
            let ratios: Vec<f64> = g
                .layers()
                .iter()
                .filter(|l| l.weight_words() > 0 && l.is_einsum())
                .map(|l| l.aw_ratio())
                .collect();
            assert!(
                geomean(&ratios) < 8.0,
                "{} geomean A/W = {}",
                g.name,
                geomean(&ratios)
            );
        }
        // Eye segmentation should skew activation-heavy.
        let eye = eye_segmentation();
        let ratios: Vec<f64> = eye
            .layers()
            .iter()
            .filter(|l| l.weight_words() > 0 && l.is_einsum())
            .map(|l| l.aw_ratio())
            .collect();
        assert!(geomean(&ratios) > 30.0, "eye geomean {}", geomean(&ratios));
    }
}
