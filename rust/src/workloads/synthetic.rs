//! Synthetic workload generators for controlled sweeps (traffic-analysis
//! figures and ablations): chains with a target A/W ratio, chains with
//! parametric skip density/distance, and the exact scenario segments of
//! Fig. 8–11.

use crate::ir::{Layer, ModelGraph, Op};
use crate::util::rng::SplitMix64;

/// A conv chain whose layers hit approximately the requested A/W ratio by
/// trading feature-map size against channel count. `aw_log10` in [-3, 4].
pub fn aw_chain(aw_log10: f64, len: usize) -> ModelGraph {
    assert!(len >= 1);
    let mut g = ModelGraph::new(format!("synthetic_aw_{aw_log10:+.1}"));
    // For an HxW map with C=K channels and 3x3 filters:
    //   A/W = (2*H*W*C) / (9*C^2) = 2*H*W / (9*C)
    // Pick H=W and C to hit the target.
    let target = 10f64.powf(aw_log10);
    // Start from a plausible channel count and solve H.
    let c = if target >= 1.0 { 16.0 } else { 256.0 };
    let hw = (target * 9.0 * c / 2.0).sqrt().round().max(2.0) as usize;
    let c = c as usize;
    for i in 0..len {
        let op = Op::conv2d(1, hw, hw, c, c, 3, 3, 1, 1);
        if i == 0 {
            g.add_root(Layer::new(format!("c{i}"), op));
        } else {
            g.push(Layer::new(format!("c{i}"), op));
        }
    }
    g
}

/// A uniform conv chain of `len` layers with residual skips of a fixed
/// `distance` inserted every `period` layers.
pub fn skip_chain(len: usize, distance: usize, period: usize) -> ModelGraph {
    assert!(distance >= 2 && period >= 1);
    let mut g = ModelGraph::new(format!("synthetic_skip_d{distance}_p{period}"));
    for i in 0..len {
        let op = Op::conv2d(1, 32, 32, 32, 32, 3, 3, 1, 1);
        if i == 0 {
            g.add_root(Layer::new(format!("c{i}"), op));
        } else {
            g.push(Layer::new(format!("c{i}"), op));
        }
    }
    let mut src = 0;
    while src + distance < len {
        g.add_edge(src, src + distance);
        src += period;
    }
    g
}

/// The Fig. 8 scenario: a pair (or quad) of equally sized conv layers that
/// pipeline at one-row granularity. Used by the traffic benches.
pub fn equal_conv_segment(depth: usize) -> ModelGraph {
    let mut g = ModelGraph::new(format!("equal_conv_d{depth}"));
    for i in 0..depth {
        let op = Op::conv2d(1, 64, 64, 64, 64, 3, 3, 1, 1);
        if i == 0 {
            g.add_root(Layer::new(format!("l{i}"), op));
        } else {
            g.push(Layer::new(format!("l{i}"), op));
        }
    }
    g
}

/// A memory-bound segment: 1×1 convs whose arithmetic intensity
/// (C MACs/word = 16) sits far below the compute/bandwidth balance point
/// (32 MACs/word at Table III rates), so op-by-op execution is DRAM-bound
/// and pipelining pays — the premise of the whole paper.
pub fn pointwise_conv_segment(depth: usize) -> ModelGraph {
    let mut g = ModelGraph::new(format!("pointwise_conv_d{depth}"));
    for i in 0..depth {
        let op = Op::conv2d(1, 128, 128, 16, 16, 1, 1, 1, 0);
        if i == 0 {
            g.add_root(Layer::new(format!("l{i}"), op));
        } else {
            g.push(Layer::new(format!("l{i}"), op));
        }
    }
    g
}

/// The Fig. 9b scenario: ResNet residual pair with 1×1 and 3×3 filters —
/// unequal MACs force unequal PE allocation.
pub fn unequal_conv_segment() -> ModelGraph {
    let mut g = ModelGraph::new("unequal_conv_1x1_3x3");
    g.add_root(Layer::new("l0", Op::conv2d(1, 56, 56, 64, 64, 1, 1, 1, 0)));
    g.push(Layer::new("l1", Op::conv2d(1, 56, 56, 64, 64, 3, 3, 1, 1)));
    g
}

/// The Fig. 9a / Fig. 11 scenario: depth-4 segment with a skip from layer 2
/// to layer 4 (RITNet-UpBlock-like traffic with a skip that must traverse
/// multiple 1-D paths on a 2-D organization).
pub fn skip_conv_segment() -> ModelGraph {
    let mut g = ModelGraph::new("skip_conv_d4");
    for i in 0..4 {
        let op = Op::conv2d(1, 64, 64, 32, 32, 3, 3, 1, 1);
        if i == 0 {
            g.add_root(Layer::new(format!("l{i}"), op));
        } else {
            g.push(Layer::new(format!("l{i}"), op));
        }
    }
    g.add_edge(1, 3); // the paper's "L2-4" skip
    g
}

/// Random conv/gemm DAG for property tests: valid by construction, varying
/// shapes, occasional skip edges.
pub fn random_model(rng: &mut SplitMix64, max_layers: usize) -> ModelGraph {
    let n_layers = rng.gen_usize(2, max_layers.max(3));
    let mut g = ModelGraph::new(format!("random_{n_layers}"));
    let mut hw = *rng.choose(&[16usize, 32, 64, 128]);
    let mut c = *rng.choose(&[8usize, 16, 32, 64]);
    for i in 0..n_layers {
        let kind = rng.gen_range(10);
        let op = match kind {
            0..=5 => {
                let k = *rng.choose(&[c, c * 2, c.max(8) / 2]);
                let r = *rng.choose(&[1usize, 3]);
                let op = Op::conv2d(1, hw, hw, c, k, r, r, 1, r / 2);
                c = k;
                op
            }
            6 => Op::dwconv2d(1, hw, hw, c, 3, 1),
            7 => {
                let op = Op::pool(1, hw, hw, c, 2, 2);
                hw = (hw / 2).max(2);
                op
            }
            8 => Op::eltwise_add(1, hw, hw, c),
            _ => {
                let m = hw * hw;
                let n = *rng.choose(&[32usize, 64, 128]);
                let op = Op::gemm(m, c, n);
                c = n;
                op
            }
        };
        if i == 0 {
            g.add_root(Layer::new(format!("r{i}"), op));
        } else {
            g.push(Layer::new(format!("r{i}"), op));
        }
    }
    // Sprinkle skip edges.
    for dst in 2..n_layers {
        if rng.gen_bool(0.2) {
            let src = rng.gen_usize(0, dst - 1);
            g.add_edge(src, dst);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aw_chain_hits_target_order_of_magnitude() {
        for target in [-2.0f64, -1.0, 0.0, 1.0, 2.0, 3.0] {
            let g = aw_chain(target, 3);
            let r = g.layer(1).aw_ratio().log10();
            assert!(
                (r - target).abs() < 0.7,
                "target 1e{target}, got 1e{r:.2}"
            );
        }
    }

    #[test]
    fn skip_chain_density() {
        let g = skip_chain(12, 3, 2);
        g.validate().unwrap();
        // src = 0,2,4,6,8 with src+3 < 12 → 0,2,4,6,8 all valid
        assert_eq!(g.skip_edges().len(), 5);
        assert!(g.skip_edges().iter().all(|e| e.dst - e.src == 3));
    }

    #[test]
    fn scenario_segments_validate() {
        equal_conv_segment(2).validate().unwrap();
        equal_conv_segment(4).validate().unwrap();
        unequal_conv_segment().validate().unwrap();
        skip_conv_segment().validate().unwrap();
    }

    #[test]
    fn unequal_segment_has_9x_mac_imbalance() {
        let g = unequal_conv_segment();
        let m0 = g.layer(0).macs();
        let m1 = g.layer(1).macs();
        assert_eq!(m1 / m0, 9); // 3x3 vs 1x1
    }

    #[test]
    fn random_models_always_validate() {
        let mut rng = SplitMix64::new(0xABCD);
        for _ in 0..200 {
            let g = random_model(&mut rng, 12);
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }
}
