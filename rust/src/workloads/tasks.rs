//! The nine XR-bench-like task models (Sec. V-B). Layer dimensions follow
//! the cited public architectures; see DESIGN.md §2 for the substitution
//! rationale.

use super::blocks::*;
use crate::ir::{Layer, ModelGraph, Op};

/// Eye segmentation — RITNet [Chaudhary et al. 2019]: DenseNet-style
/// encoder/decoder on a 320×200 eye crop with very small channel counts
/// (high A/W ratios ~1e2..1e4) and the densest skip pattern in the suite.
pub fn eye_segmentation() -> ModelGraph {
    let mut g = ModelGraph::new("eye_segmentation");
    let (mut h, mut w) = (192usize, 320usize);
    let c = 32usize;
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, h, w, 1, c, 3, 3, 1, 1)));
    // Down path: 4 dense blocks with avg-pool between.
    let mut cur = stem;
    let mut skips = Vec::new(); // encoder outputs for U-net style long skips
    for b in 0..4 {
        cur = dense_block(&mut g, cur, &format!("down{b}"), 1, h, w, c, 4);
        skips.push((cur, h, w));
        let pool = g.add_layer(
            Layer::new(format!("down{b}.pool"), Op::pool(1, h, w, c, 2, 2)),
            &[cur],
        );
        h /= 2;
        w /= 2;
        cur = pool;
    }
    // Bottleneck dense block.
    cur = dense_block(&mut g, cur, "bottleneck", 1, h, w, c, 4);
    // Up path: 4 up blocks, each receiving the matching encoder skip.
    for b in 0..4 {
        cur = up_block(&mut g, cur, &format!("up{b}"), 1, h, w, c);
        h *= 2;
        w *= 2;
        let (enc, eh, ew) = skips[3 - b];
        debug_assert_eq!((eh, ew), (h, w));
        let fuse = g.add_layer(
            Layer::new(format!("up{b}.fuse"), Op::eltwise_add(1, h, w, c)),
            &[cur],
        );
        g.add_edge(enc, fuse);
        cur = dense_block(&mut g, fuse, &format!("up{b}.dense"), 1, h, w, c, 3);
    }
    // Per-pixel segmentation head.
    g.add_layer(
        Layer::new("head", Op::conv2d(1, h, w, c, 4, 1, 1, 1, 0)),
        &[cur],
    );
    g
}

/// Gaze estimation — appearance-based CNN on 128×128 eye images (EyeCoD-style
/// [You et al. 2022]): small conv stack, moderate A/W, FC head. Fig. 13:
/// "gaze estimation does better with deeper pipelining in the activation
/// heavy regions".
pub fn gaze_estimation() -> ModelGraph {
    let mut g = ModelGraph::new("gaze_estimation");
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 128, 128, 3, 24, 3, 3, 1, 1)));
    let mut cur = residual_block(&mut g, stem, "b0", 1, 128, 128, 24, 24, 1);
    cur = g.add_layer(
        Layer::new("pool0", Op::pool(1, 128, 128, 24, 2, 2)),
        &[cur],
    );
    cur = residual_block(&mut g, cur, "b1", 1, 64, 64, 24, 48, 2);
    cur = residual_block(&mut g, cur, "b2", 1, 32, 32, 48, 48, 1);
    cur = residual_block(&mut g, cur, "b3", 1, 32, 32, 48, 96, 2);
    cur = residual_block(&mut g, cur, "b4", 1, 16, 16, 96, 96, 1);
    cur = g.add_layer(
        Layer::new("gap", Op::pool(1, 16, 16, 96, 16, 16)),
        &[cur],
    );
    // FC regression head → weight-heavy GEMMs.
    let fc0 = g.add_layer(Layer::new("fc0", Op::gemm(1, 96, 128)), &[cur]);
    g.add_layer(Layer::new("fc_gaze", Op::gemm(1, 128, 2)), &[fc0]);
    g
}

/// Depth estimation — MiDaS-small-style [Ranftl et al. 2022]: ResNet-ish
/// encoder, DWCONV-heavy (FBNet-like) decoder with one long skip per block
/// ("midas: one skip connection per block with varying reuse distance").
/// DWCONV layers are memory-bound and drive deep pipelining (Fig. 16).
pub fn depth_estimation() -> ModelGraph {
    let mut g = ModelGraph::new("depth_estimation");
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 256, 256, 3, 32, 3, 3, 2, 1)));
    // Encoder: 4 stages of inverted residual blocks.
    let mut cur = stem;
    let mut stage_outs = Vec::new();
    let dims = [
        (128usize, 32usize, 48usize),
        (64, 48, 96),
        (32, 96, 160),
        (16, 160, 256),
    ];
    for (i, &(hw, c_in, c_out)) in dims.iter().enumerate() {
        cur = inverted_residual_block(&mut g, cur, &format!("enc{i}.0"), 1, hw, hw, c_in, 4, c_out, 2);
        cur = inverted_residual_block(
            &mut g,
            cur,
            &format!("enc{i}.1"),
            1,
            hw / 2,
            hw / 2,
            c_out,
            4,
            c_out,
            1,
        );
        stage_outs.push((cur, hw / 2, c_out));
    }
    // Decoder: upsample + fuse the matching encoder stage (long skips of
    // increasing reuse distance), DWCONV refinement.
    for d in 0..3 {
        let (_, h, c) = stage_outs[3 - d];
        let (enc, eh, ec) = stage_outs[2 - d];
        let up = g.add_layer(
            Layer::new(format!("dec{d}.up"), Op::upsample(1, h, h, c, 2)),
            &[cur],
        );
        debug_assert_eq!(eh, h * 2);
        let align = g.add_layer(
            Layer::new(
                format!("dec{d}.align"),
                Op::conv2d(1, eh, eh, c, ec, 1, 1, 1, 0),
            ),
            &[up],
        );
        let fuse = g.add_layer(
            Layer::new(format!("dec{d}.fuse"), Op::eltwise_add(1, eh, eh, ec)),
            &[align],
        );
        g.add_edge(enc, fuse);
        let dw = g.add_layer(
            Layer::new(format!("dec{d}.dw"), Op::dwconv2d(1, eh, eh, ec, 3, 1)),
            &[fuse],
        );
        cur = g.add_layer(
            Layer::new(
                format!("dec{d}.pw"),
                Op::conv2d(1, eh, eh, ec, ec, 1, 1, 1, 0),
            ),
            &[dw],
        );
    }
    // Full-resolution depth head.
    let up = g.add_layer(
        Layer::new("head.up", Op::upsample(1, 64, 64, 48, 2)),
        &[cur],
    );
    let dw = g.add_layer(
        Layer::new("head.dw", Op::dwconv2d(1, 128, 128, 48, 3, 1)),
        &[up],
    );
    g.add_layer(
        Layer::new("head.depth", Op::conv2d(1, 128, 128, 48, 1, 1, 1, 1, 0)),
        &[dw],
    );
    g
}

/// Hand tracking — 3-D hand shape/pose backbone [Ge et al. 2019]: ResNet-50
/// style bottleneck stack on 256×256, deep weight-heavy stages, GEMM heads.
pub fn hand_tracking() -> ModelGraph {
    let mut g = ModelGraph::new("hand_tracking");
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 256, 256, 3, 64, 7, 7, 2, 3)));
    let mut cur = g.add_layer(
        Layer::new("pool0", Op::pool(1, 128, 128, 64, 2, 2)),
        &[stem],
    );
    // (h, c_in, c_mid, c_out, blocks, first_stride)
    let stages = [
        (64usize, 64usize, 64usize, 256usize, 2usize, 1usize),
        (64, 256, 128, 512, 2, 2),
        (32, 512, 256, 1024, 3, 2),
        (16, 1024, 512, 2048, 2, 2),
    ];
    for (s, &(h, c_in, c_mid, c_out, blocks, stride0)) in stages.iter().enumerate() {
        let mut h_cur = h;
        for b in 0..blocks {
            let stride = if b == 0 { stride0 } else { 1 };
            let ci = if b == 0 { c_in } else { c_out };
            cur = bottleneck_block(
                &mut g,
                cur,
                &format!("s{s}b{b}"),
                1,
                h_cur,
                h_cur,
                ci,
                c_mid,
                c_out,
                stride,
            );
            h_cur /= stride;
        }
    }
    let gap = g.add_layer(Layer::new("gap", Op::pool(1, 8, 8, 2048, 8, 8)), &[cur]);
    // Pose + shape heads (weight-dominant GEMMs, A/W ~ 1e-3).
    let fc0 = g.add_layer(Layer::new("fc0", Op::gemm(1, 2048, 1024)), &[gap]);
    g.add_layer(Layer::new("fc_pose", Op::gemm(1, 1024, 63)), &[fc0]);
    g
}

/// Keyword detection — res8 [Tang & Lin 2018]: 6 convs with 45 channels on
/// a 101×40 MFCC map, residual (distance-2) skips throughout. "Keyword
/// detection prefers pipelining despite nominal A/W ratios because of skip
/// connections" (Sec. VI-D).
pub fn keyword_detection() -> ModelGraph {
    let mut g = ModelGraph::new("keyword_detection");
    let c = 45usize;
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 101, 40, 1, c, 3, 3, 1, 1)));
    let pool = g.add_layer(
        Layer::new("pool0", Op::pool(1, 101, 40, c, 2, 2)),
        &[stem],
    );
    let (h, w) = (50usize, 20usize);
    let mut cur = pool;
    for b in 0..3 {
        // res8 pairs convs with an identity skip around each pair.
        let c1 = g.add_layer(
            Layer::new(
                format!("b{b}.conv0"),
                Op::conv2d(1, h, w, c, c, 3, 3, 1, 1),
            ),
            &[cur],
        );
        let c2 = g.add_layer(
            Layer::new(
                format!("b{b}.conv1"),
                Op::conv2d(1, h, w, c, c, 3, 3, 1, 1),
            ),
            &[c1],
        );
        let add = g.add_layer(
            Layer::new(format!("b{b}.add"), Op::eltwise_add(1, h, w, c)),
            &[c2],
        );
        g.add_edge(cur, add);
        cur = add;
    }
    let gap = g.add_layer(Layer::new("gap", Op::pool(1, h, w, c, h, w)), &[cur]);
    g.add_layer(Layer::new("fc", Op::gemm(1, c, 12)), &[gap]);
    g
}

/// Action segmentation — TCN [Lea et al. 2017]: dilated temporal convs over
/// long frame windows with large channel counts → weight-heavy, does not
/// favor pipelining (Fig. 13 discussion).
pub fn action_segmentation() -> ModelGraph {
    let mut g = ModelGraph::new("action_segmentation");
    let frames = 128usize;
    // Input features per frame come from a (precomputed) visual backbone.
    let stem = g.add_root(Layer::new(
        "stem",
        Op::conv2d(1, frames, 1, 2048, 256, 1, 1, 1, 0),
    ));
    let mut cur = stem;
    let mut c_in = 256usize;
    for b in 0..4 {
        let c_out = 256 + 128 * (b / 2);
        cur = tcn_block(&mut g, cur, &format!("tcn{b}"), frames, c_in, c_out, 9);
        c_in = c_out;
    }
    g.add_layer(
        Layer::new("head", Op::conv2d(1, frames, 1, c_in, 48, 1, 1, 1, 0)),
        &[cur],
    );
    g
}

/// Object detection — Faster-R-CNN style [Ren et al. 2015]: conv backbone +
/// RPN + ROIAlign (complex layers that cut pipeline segments) + GEMM heads.
pub fn object_detection() -> ModelGraph {
    let mut g = ModelGraph::new("object_detection");
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 320, 320, 3, 32, 3, 3, 2, 1)));
    let mut cur = residual_block(&mut g, stem, "b0", 1, 160, 160, 32, 64, 2);
    cur = residual_block(&mut g, cur, "b1", 1, 80, 80, 64, 128, 2);
    cur = residual_block(&mut g, cur, "b2", 1, 40, 40, 128, 256, 2);
    let feat = residual_block(&mut g, cur, "b3", 1, 20, 20, 256, 256, 1);
    // RPN — complex layer, cuts pipelining.
    let rpn = g.add_layer(Layer::new("rpn", Op::rpn(20, 20, 256, 9)), &[feat]);
    // ROIAlign over proposals.
    let roi = g.add_layer(Layer::new("roi_align", Op::roi_align(64, 7, 256)), &[rpn]);
    g.add_edge(feat, roi); // ROIAlign also reads the feature map
    // Per-ROI head: two FC layers (batched as GEMM over 64 ROIs).
    let fc0 = g.add_layer(Layer::new("head.fc0", Op::gemm(64, 7 * 7 * 256, 1024)), &[roi]);
    let fc1 = g.add_layer(Layer::new("head.fc1", Op::gemm(64, 1024, 1024)), &[fc0]);
    g.add_layer(Layer::new("head.cls", Op::gemm(64, 1024, 91)), &[fc1]);
    g
}

/// Plane detection — PlaneRCNN-style [Liu et al. 2019]: detection backbone +
/// complex layers + a segmentation-ish decoder with long skips.
pub fn plane_detection() -> ModelGraph {
    let mut g = ModelGraph::new("plane_detection");
    let stem = g.add_root(Layer::new("stem", Op::conv2d(1, 256, 256, 3, 32, 3, 3, 2, 1)));
    let e0 = residual_block(&mut g, stem, "e0", 1, 128, 128, 32, 64, 2);
    let e1 = residual_block(&mut g, e0, "e1", 1, 64, 64, 64, 128, 2);
    let e2 = residual_block(&mut g, e1, "e2", 1, 32, 32, 128, 256, 2);
    // RPN + ROIAlign for plane proposals.
    let rpn = g.add_layer(Layer::new("rpn", Op::rpn(16, 16, 256, 9)), &[e2]);
    let roi = g.add_layer(Layer::new("roi_align", Op::roi_align(32, 7, 256)), &[rpn]);
    g.add_edge(e2, roi);
    // Plane-mask decoder: upsample with skips back to encoder stages.
    let up0 = g.add_layer(
        Layer::new("d0.up", Op::upsample(1, 16, 16, 256, 2)),
        &[roi],
    );
    let d0 = g.add_layer(
        Layer::new("d0.conv", Op::conv2d(1, 32, 32, 256, 128, 3, 3, 1, 1)),
        &[up0],
    );
    let f0 = g.add_layer(
        Layer::new("d0.fuse", Op::eltwise_add(1, 32, 32, 128)),
        &[d0],
    );
    g.add_edge(e1, f0);
    let up1 = g.add_layer(Layer::new("d1.up", Op::upsample(1, 32, 32, 128, 2)), &[f0]);
    let d1 = g.add_layer(
        Layer::new("d1.conv", Op::conv2d(1, 64, 64, 128, 64, 3, 3, 1, 1)),
        &[up1],
    );
    let f1 = g.add_layer(
        Layer::new("d1.fuse", Op::eltwise_add(1, 64, 64, 64)),
        &[d1],
    );
    g.add_edge(e0, f1);
    g.add_layer(
        Layer::new("mask_head", Op::conv2d(1, 64, 64, 64, 1, 1, 1, 1, 0)),
        &[f1],
    );
    g
}

/// World locking / speech — Emformer-style streaming acoustic model
/// [Shi et al. 2021]: GEMM-dominated transformer blocks at small chunk
/// length → strongly weight-heavy (A/W down to ~1e-3).
pub fn world_locking() -> ModelGraph {
    let mut g = ModelGraph::new("world_locking");
    let seq = 32usize; // streaming chunk
    let d = 512usize;
    let stem = g.add_root(Layer::new("embed", Op::gemm(seq, 80, d)));
    let mut cur = stem;
    for b in 0..4 {
        // Self-attention projections (Q,K,V fused) + output proj.
        let qkv = g.add_layer(
            Layer::new(format!("l{b}.qkv"), Op::gemm(seq, d, 3 * d)),
            &[cur],
        );
        // Attention score + context as batched GEMMs over 8 heads.
        let score = g.add_layer(
            Layer::new(format!("l{b}.score"), Op::gemm(8 * seq, d / 8, seq)),
            &[qkv],
        );
        let ctx = g.add_layer(
            Layer::new(format!("l{b}.ctx"), Op::gemm(8 * seq, seq, d / 8)),
            &[score],
        );
        let proj = g.add_layer(
            Layer::new(format!("l{b}.proj"), Op::gemm(seq, d, d)),
            &[ctx],
        );
        let add = g.add_layer(
            Layer::new(format!("l{b}.attn_add"), Op::eltwise_add(1, seq, 1, d)),
            &[proj],
        );
        g.add_edge(cur, add);
        cur = ffn_block(&mut g, add, &format!("l{b}"), seq, d);
    }
    g.add_layer(Layer::new("ctc_head", Op::gemm(seq, d, 4096)), &[cur]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::skips::SkipProfile;

    #[test]
    fn eye_segmentation_structure() {
        let g = eye_segmentation();
        g.validate().unwrap();
        assert!(g.num_layers() > 40, "{}", g.num_layers());
        let p = SkipProfile::of(&g);
        assert!(p.num_skips() > 20, "dense skips expected, got {}", p.num_skips());
        // distances vary (dense block internal 2..4 plus long U-net skips)
        let dists: std::collections::BTreeSet<usize> =
            p.edges.iter().map(|&(_, _, d)| d).collect();
        assert!(dists.len() >= 3, "distances {dists:?}");
    }

    #[test]
    fn depth_estimation_has_dwconv_and_long_skips() {
        let g = depth_estimation();
        g.validate().unwrap();
        assert!(g
            .layers()
            .iter()
            .any(|l| l.op.kind() == crate::ir::OpKind::DwConv2d));
        let p = SkipProfile::of(&g);
        assert!(p.max_distance >= 8, "max dist {}", p.max_distance);
    }

    #[test]
    fn hand_tracking_is_deep_and_weight_heavy_late() {
        let g = hand_tracking();
        g.validate().unwrap();
        let last_gemm = g
            .layers()
            .iter()
            .find(|l| l.name == "fc0")
            .expect("fc0 present");
        assert!(last_gemm.aw_ratio() < 0.01);
    }

    #[test]
    fn keyword_detection_residual_distance_two() {
        let g = keyword_detection();
        g.validate().unwrap();
        let p = SkipProfile::of(&g);
        assert_eq!(p.num_skips(), 3);
        // skip wraps conv0→conv1→add, i.e. reuse distance 3 in layer order
        assert!(p.edges.iter().all(|&(_, _, d)| d == 3));
    }

    #[test]
    fn object_detection_pipeline_cutters() {
        let g = object_detection();
        g.validate().unwrap();
        let complex: Vec<_> = g.layers().iter().filter(|l| l.is_complex()).collect();
        assert_eq!(complex.len(), 2); // RPN + ROIAlign
    }

    #[test]
    fn world_locking_gemm_only_compute() {
        let g = world_locking();
        g.validate().unwrap();
        assert!(g
            .layers()
            .iter()
            .filter(|l| l.is_einsum())
            .all(|l| l.op.kind() == crate::ir::OpKind::Gemm));
    }

    #[test]
    fn models_have_distinct_names() {
        let names = super::super::task_names();
        let set: std::collections::BTreeSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len());
    }
}
