//! Integration tests for latency attribution and the flight recorder:
//! per-request conservation is bit-exact on every canned scenario ×
//! policy, attribution JSON is byte-identical across worker counts, the
//! flight recorder freezes exactly at the first deadline miss (and falls
//! back to an end-of-run snapshot when nothing missed), its frozen
//! document satisfies the same schema `tools/trace_check.py` enforces,
//! and turning attribution off changes no simulation result.

use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{canned_scenarios, scenario_by_name, CoschedConfig, Scenario, TaskSpec};
use pipeorgan::dse::EvalCache;
use pipeorgan::obs::{FlightTrigger, DEFAULT_FLIGHT_CAP};
use pipeorgan::report;
use pipeorgan::serve::{
    plan_scenario, run_scenario, simulate, streams, ArrivalProcess, BandwidthModel, Policy,
    ServeConfig, ServeRun, SimOptions,
};
use pipeorgan::util::json::Json;
use pipeorgan::workloads::synthetic;

fn small_cfg() -> ArchConfig {
    ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    }
}

/// A fast two-task scenario whose deadlines can be pinned per test.
fn pair_scenario(deadline_ms: Option<f64>) -> Scenario {
    let mut a = synthetic::aw_chain(2.0, 4);
    a.name = "a".into();
    let mut b = synthetic::pointwise_conv_segment(2);
    b.name = "b".into();
    let spec = |g, rate| {
        let t = TaskSpec::new(g, rate);
        match deadline_ms {
            Some(d) => t.with_deadline_ms(d),
            None => t,
        }
    };
    Scenario::new("pair", vec![spec(a, 100.0), spec(b, 100.0)])
}

/// Tentpole invariant: every per-request record's components sum back to
/// the measured latency with residual exactly `0.0` — not approximately —
/// on every canned scenario, policy, and load level, and the record
/// counts close against the per-task metrics.
#[test]
fn attribution_conserves_bit_exactly_on_every_canned_scenario_and_policy() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        for mult in [1.0, 8.0] {
            let arrivals = streams(&sc, &ArrivalProcess::Periodic, mult, 0.05, 0);
            for policy in Policy::ALL {
                let out = simulate(&sc, &plan, policy, &arrivals, SimOptions::default());
                let ended: u64 = out.tasks.iter().map(|t| t.completed + t.dropped).sum();
                assert_eq!(
                    out.attr.len() as u64,
                    ended,
                    "{} {} @ {mult}x: one record per ended request",
                    sc.name,
                    policy.name()
                );
                let mut missed = 0u64;
                for a in &out.attr {
                    assert_eq!(
                        a.residual_s(),
                        0.0,
                        "{} {} @ {mult}x task {} req {}: residual must be exactly zero",
                        sc.name,
                        policy.name(),
                        a.task,
                        a.id
                    );
                    assert!(a.queue_s >= 0.0 && a.floor_s >= 0.0 && a.stretch_s >= 0.0);
                    if a.missed() {
                        missed += 1;
                    }
                    if !a.completed() {
                        // A drop's whole lifetime is queue wait.
                        assert_eq!(a.latency_s, a.queue_s);
                        assert_eq!((a.floor_s, a.stretch_s, a.donation_s), (0.0, 0.0, 0.0));
                        assert_eq!(a.dominant(), "policy");
                    }
                }
                assert_eq!(
                    missed,
                    out.total_missed(),
                    "{} {} @ {mult}x: SLO accounting must agree with metrics",
                    sc.name,
                    policy.name()
                );
            }
        }
    }
}

/// Attribution is part of the determinism witness: the exported JSON is
/// byte-identical across 1/2/4 workers at a fixed seed (workers only
/// parallelize planning, never the simulation).
#[test]
fn attribution_json_is_byte_identical_across_worker_counts() {
    let cfg = small_cfg();
    let sc = scenario_by_name("xr-core").unwrap();
    let sv = ServeConfig {
        duration_s: 0.05,
        arrivals: ArrivalProcess::Poisson,
        seed: 7,
        ..ServeConfig::default()
    };
    let render = |r: &ServeRun| -> Vec<String> {
        r.outcomes
            .iter()
            .map(|o| {
                let mut arr = Json::Arr(vec![]);
                for a in &o.attr {
                    arr.push(a.to_json());
                }
                arr.to_pretty()
            })
            .collect()
    };
    let base = render(&run_scenario(&sc, &cfg, &sv, &EvalCache::new(), 1).unwrap());
    assert!(!base.is_empty() && base.iter().all(|s| s.len() > 2));
    for workers in [2usize, 4] {
        let other = render(&run_scenario(&sc, &cfg, &sv, &EvalCache::new(), workers).unwrap());
        assert_eq!(base, other, "attr JSON diverged at {workers} workers");
    }
}

/// The flight recorder freezes on the *first* deadline miss — a late
/// completion under FIFO, a policy drop under EDF — and the trigger
/// identifies exactly the first SLO-missing attribution record.
#[test]
fn flight_recorder_freezes_on_the_first_miss() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    // Deadlines far below any service time: every request misses, so a
    // trigger is guaranteed on the very first ended request.
    let sc = pair_scenario(Some(1e-4));
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
    let arrivals = streams(&sc, &ArrivalProcess::Periodic, 1.0, 0.05, 0);
    let opts = SimOptions {
        flight: Some(DEFAULT_FLIGHT_CAP),
        ..SimOptions::default()
    };
    for policy in [Policy::Fifo, Policy::Edf] {
        let out = simulate(&sc, &plan, policy, &arrivals, opts);
        assert!(out.total_missed() > 0, "{}: fixture must miss", policy.name());
        let snap = out.flight.as_ref().expect("armed recorder returns a snapshot");
        assert!(snap.missed(), "{}: miss run must freeze on the miss", policy.name());
        let first = out.attr.iter().find(|a| a.missed()).expect("a missed record");
        match snap.trigger {
            FlightTrigger::DeadlineMiss { task, id, region, t_s } => {
                assert_eq!(
                    (task, id, region),
                    (first.task, first.id, first.region),
                    "{}: trigger must be the first miss, not a later one",
                    policy.name()
                );
                assert!(
                    (t_s - (first.arrival_s + first.latency_s)).abs() <= 1e-9,
                    "{}: trigger time {} vs first miss end {}",
                    policy.name(),
                    t_s,
                    first.arrival_s + first.latency_s
                );
            }
            FlightTrigger::EndOfRun { .. } => panic!("{}: wrong trigger", policy.name()),
        }
    }
}

/// With generous deadlines nothing misses, and `finish` falls back to an
/// end-of-run snapshot covering the whole span.
#[test]
fn flight_recorder_falls_back_to_end_of_run_without_misses() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = pair_scenario(Some(10_000.0));
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
    let arrivals = streams(&sc, &ArrivalProcess::Periodic, 1.0, 0.05, 0);
    let out = simulate(
        &sc,
        &plan,
        Policy::Fifo,
        &arrivals,
        SimOptions {
            flight: Some(DEFAULT_FLIGHT_CAP),
            ..SimOptions::default()
        },
    );
    assert_eq!(out.total_missed(), 0, "fixture must not miss");
    let snap = out.flight.as_ref().unwrap();
    assert!(!snap.missed());
    match snap.trigger {
        FlightTrigger::EndOfRun { t_s } => {
            assert!((t_s - out.span_s).abs() <= 1e-9, "{t_s} vs span {}", out.span_s)
        }
        FlightTrigger::DeadlineMiss { .. } => panic!("nothing missed"),
    }
}

/// The flight document satisfies the same schema `tools/trace_check.py`
/// enforces on full `--trace-out` exports: non-empty traceEvents each
/// carrying ph/ts/pid/tid, all four counter tracks, named region tracks —
/// plus the `flight` block with its trigger and attribution table.
#[test]
fn flight_document_mirrors_the_trace_schema() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = pair_scenario(Some(1e-4));
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
    let arrivals = streams(&sc, &ArrivalProcess::Periodic, 1.0, 0.05, 0);
    let out = simulate(
        &sc,
        &plan,
        Policy::Fifo,
        &arrivals,
        SimOptions {
            flight: Some(DEFAULT_FLIGHT_CAP),
            ..SimOptions::default()
        },
    );
    let snap = out.flight.as_ref().unwrap();
    let doc = snap.document(report::flight_table_json(&out));
    let parsed = Json::parse(&doc.to_pretty()).unwrap();

    let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    assert!(!events.is_empty(), "frozen snippet must carry events");
    let mut counters = std::collections::BTreeSet::new();
    let mut thread_names = 0usize;
    for ev in events {
        for key in ["ph", "ts", "pid", "tid"] {
            assert!(ev.get(key).is_some(), "event missing {key}: {}", ev.to_pretty());
        }
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap();
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("");
        if ph == "M" && name == "thread_name" {
            thread_names += 1;
        }
        if ph == "C" {
            counters.insert(name.to_string());
            let args = ev.get("args").expect("counter carries an args series");
            assert!(matches!(args, Json::Obj(_)));
        }
    }
    for want in ["queue_depth", "dram_bw", "region_util", "worst_channel_load"] {
        assert!(counters.contains(want), "missing counter {want} (have {counters:?})");
    }
    assert!(thread_names > 0, "region tracks must be named");

    let flight = parsed.get("flight").expect("flight block");
    assert_eq!(flight.get("kind").and_then(|k| k.as_str()), Some("deadline_miss"));
    assert!(flight.get("t_s").and_then(|t| t.as_f64()).is_some());
    let table = flight.get("table").expect("attribution table rides along");
    assert!(!table.get("worst").and_then(|w| w.as_arr()).unwrap().is_empty());
}

/// Attribution and the flight recorder are observers: turning them off
/// (the sweep-probe configuration) changes no simulation result.
#[test]
fn disabling_attribution_changes_no_results() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    let arrivals = streams(&sc, &ArrivalProcess::Periodic, 4.0, 0.05, 0);
    for policy in Policy::ALL {
        let on = simulate(
            &sc,
            &plan,
            policy,
            &arrivals,
            SimOptions {
                flight: Some(DEFAULT_FLIGHT_CAP),
                ..SimOptions::default()
            },
        );
        let off = simulate(
            &sc,
            &plan,
            policy,
            &arrivals,
            SimOptions {
                record_attr: false,
                flight: None,
                ..SimOptions::default()
            },
        );
        assert!(off.attr.is_empty() && off.flight.is_none());
        assert!(!on.attr.is_empty());
        assert_eq!(on.tasks, off.tasks, "{}", policy.name());
        assert_eq!(on.trace, off.trace, "{}", policy.name());
        assert_eq!(on.span_s, off.span_s, "{}", policy.name());
    }
}

/// Donation semantics: the static bandwidth model never donates (service
/// runs at exactly the entitled share), while the dynamic model only ever
/// speeds service up — donations are non-negative.
#[test]
fn donation_is_zero_under_static_and_nonnegative_under_dynamic() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-hands").unwrap();
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    let arrivals = streams(&sc, &ArrivalProcess::Periodic, 2.0, 0.05, 0);
    let run = |bandwidth| {
        simulate(
            &sc,
            &plan,
            Policy::Fifo,
            &arrivals,
            SimOptions {
                bandwidth,
                ..SimOptions::default()
            },
        )
    };
    let stat = run(BandwidthModel::Static);
    for a in stat.attr.iter().filter(|a| a.completed()) {
        assert_eq!(a.donated_bytes, 0.0, "static split grants exactly the entitlement");
        assert!(
            a.donation_s.abs() <= 1e-9 + 1e-6 * a.latency_s,
            "task {} req {}: static donation {} should be ~0",
            a.task,
            a.id,
            a.donation_s
        );
    }
    let dynamic = run(BandwidthModel::Dynamic);
    for a in dynamic.attr.iter().filter(|a| a.completed()) {
        assert!(
            a.donation_s >= -(1e-9 + 1e-6 * a.latency_s),
            "task {} req {}: dynamic donation {} must not be negative",
            a.task,
            a.id,
            a.donation_s
        );
        assert!(a.donated_bytes >= 0.0);
    }
}
