//! Integration tests for the co-scheduling subsystem: the never-lose
//! guarantee against the naive even split on every canned XR scenario, the
//! structural non-overlap of composed scenario placements, the shared
//! persistent-cache warm path, the strict CLI flag policy of the `cosched`
//! subcommand, and the report emitter.

use pipeorgan::cli::Args;
use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{
    canned_live_contexts, canned_scenarios, even_widths, region_config, scenario_by_name,
    schedule, CoschedConfig, CutTree, PartitionKind, Region, RegionPartition, COSCHED_FLAGS,
};
use pipeorgan::dse::EvalCache;
use pipeorgan::report::cosched_report;

/// A smaller array than Table III keeps debug-build evaluation fast; every
/// asserted property is architecture-independent.
fn small_cfg() -> ArchConfig {
    ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    }
}

fn quick_cs() -> CoschedConfig {
    CoschedConfig {
        quantum: 4,
        ..CoschedConfig::default()
    }
}

fn guillotine_cs() -> CoschedConfig {
    CoschedConfig {
        partition: PartitionKind::Guillotine,
        quantum: 4,
        ..CoschedConfig::default()
    }
}

/// The acceptance criterion: on every canned scenario, the co-scheduled
/// allocation's makespan never exceeds the naive even split's (the
/// even-split seed makes this a construction guarantee, not luck), and the
/// whole scenario runs end to end.
#[test]
fn cosched_never_worse_than_even_split_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let r = schedule(&sc, &cfg, &quick_cs(), &cache, 2)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        assert!(
            r.cosched.makespan_cycles <= r.even_split.makespan_cycles * 1.0001,
            "{}: cosched {} vs even split {}",
            sc.name,
            r.cosched.makespan_cycles,
            r.even_split.makespan_cycles
        );
        assert!(r.speedup() >= 0.9999, "{}: speedup {}", sc.name, r.speedup());
        // Every task is assigned in every mode, with positive work.
        for o in [&r.solo, &r.even_split, &r.cosched] {
            assert_eq!(o.assignments.len(), sc.tasks.len(), "{} {}", sc.name, o.mode);
            assert!(o.makespan_cycles > 0.0, "{} {}", sc.name, o.mode);
            for a in &o.assignments {
                assert!(
                    a.latency_cycles > 0.0 && a.energy > 0.0,
                    "{} {} {}",
                    sc.name,
                    o.mode,
                    a.task
                );
            }
        }
    }
}

#[test]
fn scenario_placement_is_non_overlapping_and_covers_every_task() {
    let cfg = small_cfg();
    let sc = scenario_by_name("xr-core").expect("canned scenario");
    let r = schedule(&sc, &cfg, &quick_cs(), &EvalCache::new(), 2).unwrap();
    let sp = &r.placement;
    assert_eq!((sp.rows, sp.cols), (cfg.pe_rows, cfg.pe_cols));
    // Each PE belongs to at most one task (compose() rejects overlap), and
    // the per-task counts plus idle PEs tile the array exactly.
    let owned: usize = (0..sc.tasks.len()).map(|t| sp.task_pes(t)).sum();
    assert_eq!(owned + sp.idle_pes(), cfg.num_pes());
    for t in 0..sc.tasks.len() {
        assert!(sp.task_pes(t) > 0, "task {t} got no PEs");
    }
    // The regions of the co-scheduled outcome validate as a partition.
    let widths: Vec<usize> = r.cosched.assignments.iter().map(|a| a.region.cols).collect();
    RegionPartition::vertical(cfg.pe_rows, cfg.pe_cols, &widths)
        .validate()
        .unwrap();
    // Rendering is one row per array row.
    assert_eq!(sp.render().lines().count(), cfg.pe_rows);
}

#[test]
fn shared_cache_warms_across_scenarios_and_reruns() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let cold = schedule(&sc, &cfg, &quick_cs(), &cache, 1).unwrap();
    assert!(cold.evaluations > 0);
    let warm = schedule(&sc, &cfg, &quick_cs(), &cache, 1).unwrap();
    assert_eq!(warm.evaluations, 0, "rescheduling must be fully memoized");
    assert!(warm.cache_hits > 0);
    assert_eq!(
        warm.cosched.makespan_cycles,
        cold.cosched.makespan_cycles,
        "memoized reschedule must agree"
    );
    // The reported live contexts cover everything this run touched, so the
    // eviction path can never prune this scenario's own entries.
    let touched = cache.touched_contexts();
    let live: std::collections::HashSet<u64> = cold.contexts.iter().copied().collect();
    assert!(
        touched.is_subset(&live),
        "live contexts must cover touched contexts"
    );
    assert_eq!(cache.retain_contexts(&live), 0);
}

/// One shared cache file must stay warm across subcommands: the
/// statically-known canned live set (what every subcommand's save keeps)
/// covers everything a default-quantum canned-scenario run touches, so a
/// later `dse`/`e2e` save can never prune a default cosched run's entries.
#[test]
fn canned_live_contexts_cover_default_runs() {
    let cfg = small_cfg();
    let live = canned_live_contexts(&cfg);
    assert!(!live.is_empty());
    let sc = scenario_by_name("xr-core").unwrap();
    let r = schedule(&sc, &cfg, &quick_cs(), &EvalCache::new(), 1).unwrap();
    for ctx in &r.contexts {
        assert!(live.contains(ctx), "context {ctx:x} missing from canned live set");
    }
}

#[test]
fn solo_uses_the_full_array_and_sums_busy_time() {
    let cfg = small_cfg();
    let sc = scenario_by_name("xr-hands").unwrap();
    let r = schedule(&sc, &cfg, &quick_cs(), &EvalCache::new(), 2).unwrap();
    let sum: f64 = r.solo.assignments.iter().map(|a| a.busy_cycles).sum();
    assert!((r.solo.makespan_cycles - sum).abs() <= 1e-6 * sum);
    for a in &r.solo.assignments {
        assert_eq!(a.region.cols, cfg.pe_cols, "{}", a.task);
        assert_eq!(a.region.rows, cfg.pe_rows, "{}", a.task);
        assert_eq!(a.busy_cycles, a.latency_cycles * a.invocations as f64);
    }
}

#[test]
fn region_configs_scale_shared_resources() {
    let cfg = small_cfg();
    let region = Region {
        row0: 0,
        col0: 0,
        rows: 16,
        cols: 4,
    };
    let rc = region_config(&cfg, &region);
    rc.validate().unwrap();
    assert_eq!(rc.num_pes(), 64);
    assert_eq!(rc.sram_bytes, cfg.sram_bytes / 4);
    assert!((rc.dram_bytes_per_cycle - cfg.dram_bytes_per_cycle / 4.0).abs() < 1e-9);
    assert_eq!(even_widths(16, 3).iter().sum::<usize>(), 16);
}

#[test]
fn cosched_cli_flags_are_strict() {
    let mut flags: Vec<(&str, bool)> = vec![("out", true), ("workers", true), ("config", true)];
    flags.extend_from_slice(COSCHED_FLAGS);
    let ok = |v: &[&str]| {
        let raw: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        Args::parse(&raw, &flags)
    };
    let args = ok(&[
        "cosched",
        "--scenario",
        "xr-core",
        "--quantum",
        "2",
        "--cache-file",
        "reports/dse_cache.json",
        "--cache-cap",
        "1000",
    ])
    .unwrap();
    let cs = CoschedConfig::from_cli(&args).unwrap();
    assert_eq!(cs.quantum, 2);
    assert!(!cs.tuned);
    // Typos and dse-only flags stay hard errors on cosched.
    assert!(ok(&["cosched", "--scenari", "xr-core"]).is_err());
    assert!(ok(&["cosched", "--beam", "4"]).is_err());
}

#[test]
fn cosched_report_emits_to_disk() {
    let cfg = small_cfg();
    let sc = scenario_by_name("xr-core").unwrap();
    let r = schedule(&sc, &cfg, &quick_cs(), &EvalCache::new(), 2).unwrap();
    let report = cosched_report(&cfg, &[r]);
    let dir = std::env::temp_dir().join(format!("pipeorgan_cosched_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    report.emit(&dir).unwrap();
    assert!(dir.join("cosched.csv").exists());
    let text = std::fs::read_to_string(dir.join("cosched.json")).unwrap();
    let json = pipeorgan::util::json::Json::parse(&text).unwrap();
    let scenarios = json.get("scenarios").and_then(|s| s.as_arr()).unwrap();
    assert_eq!(scenarios.len(), 1);
    let s0 = &scenarios[0];
    assert_eq!(s0.get("scenario").and_then(|v| v.as_str()), Some("xr-core"));
    let speedup = s0
        .get("speedup_vs_even_split")
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!(speedup >= 0.9999, "speedup {speedup}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The tentpole acceptance criterion: on every canned scenario, the 2-D
/// guillotine plan's makespan never exceeds the vertical-band plan's (the
/// band-winner seed makes this a construction guarantee), the winning cut
/// tree realizes exactly the reported regions, and the composed placement
/// is non-overlapping and covers every task.
#[test]
fn guillotine_never_worse_than_bands_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let bands = schedule(&sc, &cfg, &quick_cs(), &cache, 2)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        let g = schedule(&sc, &cfg, &guillotine_cs(), &cache, 2)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        assert!(
            g.cosched.makespan_cycles <= bands.cosched.makespan_cycles * 1.0001,
            "{}: guillotine {} vs bands {}",
            sc.name,
            g.cosched.makespan_cycles,
            bands.cosched.makespan_cycles
        );
        // And transitively never worse than the naive even split.
        assert!(g.speedup() >= 0.9999, "{}: speedup {}", sc.name, g.speedup());
        // The tree realizes the reported geometry bit for bit. (A pure
        // guillotine winner tiles the array exactly; when the band seed
        // wins, its unused columns are an explicit idle rectangle.)
        let (p, topos) = g.cut_tree.partition(cfg.pe_rows, cfg.pe_cols).unwrap();
        p.validate().unwrap();
        let region_pes: usize = p.regions.iter().map(Region::num_pes).sum();
        assert_eq!(region_pes + p.idle_pes(), cfg.num_pes(), "{}", sc.name);
        for (task, a) in g.cosched.assignments.iter().enumerate() {
            assert_eq!(p.regions[task], a.region, "{} task {task}", sc.name);
            assert_eq!(topos[task], a.topology, "{} task {task}", sc.name);
        }
        // Composed placement: every PE at most one task, all tasks placed.
        let sp = &g.placement;
        let owned: usize = (0..sc.tasks.len()).map(|t| sp.task_pes(t)).sum();
        assert_eq!(owned + sp.idle_pes(), cfg.num_pes(), "{}", sc.name);
        for t in 0..sc.tasks.len() {
            assert!(sp.task_pes(t) > 0, "{}: task {t} got no PEs", sc.name);
        }
    }
}

/// The winning guillotine plan serializes through the report JSON format
/// and comes back identical — the round-trip the reports rely on.
#[test]
fn guillotine_plan_round_trips_through_json() {
    let cfg = small_cfg();
    let sc = scenario_by_name("xr-hands").unwrap();
    let r = schedule(&sc, &cfg, &guillotine_cs(), &EvalCache::new(), 2).unwrap();
    assert_eq!(r.partition, PartitionKind::Guillotine);
    let text = r.cut_tree.to_json().to_pretty();
    let parsed = pipeorgan::util::json::Json::parse(&text).unwrap();
    let back = CutTree::from_json(&parsed).unwrap();
    assert_eq!(back, r.cut_tree);
    assert_eq!(back.num_leaves(), sc.tasks.len());
    // The canned live set covers guillotine runs at the default quantum,
    // so shared cache files keep 2-D co-scheduling warm across saves.
    let live = canned_live_contexts(&cfg);
    for ctx in &r.contexts {
        assert!(live.contains(ctx), "context {ctx:x} missing from canned live set");
    }
}

/// The acceptance criterion of the parallel beam: fanning the per-level
/// state expansion over worker threads must be invisible — plan, makespan
/// (bit-exact), and cut-tree encoding all identical to a forced
/// single-thread run, on every canned scenario. One warm cache is shared
/// across all runs so worker counts can't diverge through costing either.
#[test]
fn parallel_beam_is_bit_identical_to_single_thread_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let serial = schedule(&sc, &cfg, &guillotine_cs(), &cache, 1)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        for workers in [2, 4, 7] {
            let par = schedule(&sc, &cfg, &guillotine_cs(), &cache, workers)
                .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
            assert_eq!(
                serial.cut_tree.encode(),
                par.cut_tree.encode(),
                "{} @ {workers} workers: plans diverged",
                sc.name
            );
            assert_eq!(
                serial.cosched.makespan_cycles.to_bits(),
                par.cosched.makespan_cycles.to_bits(),
                "{} @ {workers} workers: makespan diverged",
                sc.name
            );
            assert_eq!(
                serial.cosched.energy.to_bits(),
                par.cosched.energy.to_bits(),
                "{} @ {workers} workers: energy diverged",
                sc.name
            );
            for (a, b) in serial
                .cosched
                .assignments
                .iter()
                .zip(&par.cosched.assignments)
            {
                assert_eq!(a.region, b.region, "{}: regions diverged", sc.name);
                assert_eq!(a.topology, b.topology, "{}: topologies diverged", sc.name);
                assert_eq!(
                    a.latency_cycles.to_bits(),
                    b.latency_cycles.to_bits(),
                    "{}: latencies diverged",
                    sc.name
                );
            }
        }
    }
}
