//! Integration tests for the DSE engine: end-to-end exploration over real
//! zoo workloads, the heuristic-vs-tuned-vs-oracle guarantees, the
//! persistent-cache warm-start path, the strict CLI flag policy for the
//! `dse` subcommand, and the enumeration invariants the search relies on
//! (granularity floor, organization coverage).

use pipeorgan::cli::Args;
use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::cost::{evaluate, Mapper};
use pipeorgan::dataflow::{choose_dataflow, LoopNest};
use pipeorgan::dse::{
    dominates, explore, legal_depths, segment_candidates, CacheLoadOutcome, DseConfig, EvalCache,
    ParetoPoint, SearchStrategy, DSE_FLAGS,
};
use pipeorgan::mapper::{
    clamp_granularity, organization_candidates, PipeOrgan, TunedPipeOrgan, TUNED_MAPPER_NAME,
};
use pipeorgan::pipeline::{pair_granularity, Segment};
use pipeorgan::report::run_dse_reports;
use pipeorgan::spatial::{choose_organization, Organization, Placement};
use pipeorgan::workloads;

/// A smaller array than Table III keeps debug-build evaluation fast; every
/// asserted property is architecture-independent.
fn small_cfg() -> ArchConfig {
    ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    }
}

fn quick_dse() -> DseConfig {
    DseConfig {
        strategy: SearchStrategy::Beam,
        beam_width: 6,
        depth_cap: 4,
        ladder_rungs: 2,
        topologies: vec![TopologyKind::Amp],
        budget: None,
        max_labels: 64,
        channel_load_objective: false,
        obs: Default::default(),
    }
}

/// ≥3 zoo workloads for the end-to-end assertions (acceptance criterion).
fn zoo_tasks() -> Vec<pipeorgan::ir::ModelGraph> {
    vec![
        workloads::keyword_detection(),
        workloads::gaze_estimation(),
        workloads::action_segmentation(),
    ]
}

#[test]
fn oracle_best_never_costlier_than_heuristic_on_zoo() {
    let cfg = small_cfg();
    let dse = quick_dse();
    for g in zoo_tasks() {
        let cache = EvalCache::new();
        let r = explore(&g, &cfg, &dse, &cache, 1);
        assert!(
            r.best().cycles <= r.heuristic.cycles * 1.0001,
            "{}: oracle {} worse than heuristic {}",
            g.name,
            r.best().cycles,
            r.heuristic.cycles
        );
        r.best()
            .plan
            .validate(&g, &cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", g.name));
    }
}

#[test]
fn frontier_points_are_valid_and_mutually_non_dominating() {
    let cfg = small_cfg();
    let dse = quick_dse();
    for g in zoo_tasks() {
        let cache = EvalCache::new();
        let r = explore(&g, &cfg, &dse, &cache, 1);
        assert!(!r.frontier.is_empty(), "{}", g.name);
        for p in &r.frontier {
            p.plan
                .validate(&g, &cfg)
                .unwrap_or_else(|e| panic!("{} / {}: {e}", g.name, p.plan.mapper_name));
        }
        for (i, a) in r.frontier.iter().enumerate() {
            for (j, b) in r.frontier.iter().enumerate() {
                assert!(
                    i == j || !dominates(&a.objectives(), &b.objectives()),
                    "{}: frontier point {i} dominates {j}",
                    g.name
                );
            }
        }
    }
}

#[test]
fn dse_reports_emit_frontier_json_and_gap_table() {
    let cfg = small_cfg();
    let dse = quick_dse();
    let reports = run_dse_reports(&cfg, zoo_tasks(), &dse, 2, &EvalCache::new());
    assert_eq!(reports.len(), 2);

    let dir = std::env::temp_dir().join(format!("pipeorgan_dse_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for r in &reports {
        r.emit(&dir).unwrap();
    }
    let frontier_text = std::fs::read_to_string(dir.join("dse_frontier.json")).unwrap();
    let frontier = pipeorgan::util::json::Json::parse(&frontier_text).unwrap();
    let tasks = frontier.get("workloads").and_then(|w| w.as_arr()).unwrap();
    assert_eq!(tasks.len(), 3, "one frontier entry per workload");
    for t in tasks {
        assert!(t.get("frontier").and_then(|f| f.as_arr()).is_some());
        assert!(t.get("best").is_some() && t.get("heuristic").is_some());
    }
    let gap_text = std::fs::read_to_string(dir.join("dse_gap.json")).unwrap();
    let gap = pipeorgan::util::json::Json::parse(&gap_text).unwrap();
    for t in gap.get("workloads").and_then(|w| w.as_arr()).unwrap() {
        let heur = t.get("heuristic_cycles").and_then(|x| x.as_f64()).unwrap();
        let tuned = t.get("tuned_cycles").and_then(|x| x.as_f64()).unwrap();
        let orac = t.get("oracle_cycles").and_then(|x| x.as_f64()).unwrap();
        assert!(
            tuned <= heur * 1.0001,
            "gap table must never show tuned losing to the heuristic: {tuned} vs {heur}"
        );
        assert!(
            orac <= tuned * 1.0001,
            "gap table must never show the oracle losing to tuned: {orac} vs {tuned}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- the tuned mapper on real zoo workloads (acceptance criteria) ----------

#[test]
fn tuned_matches_or_beats_heuristic_on_all_three_zoo_workloads() {
    let cfg = small_cfg();
    let dse = quick_dse();
    let cache = EvalCache::new();
    for g in zoo_tasks() {
        let r = explore(&g, &cfg, &dse, &cache, 1);
        assert!(
            r.tuned.cycles <= r.heuristic.cycles * 1.0001,
            "{}: tuned {} must match or beat heuristic {}",
            g.name,
            r.tuned.cycles,
            r.heuristic.cycles
        );
        assert!(r.tuned_gap() >= 0.9999, "{}", g.name);
        r.tuned
            .plan
            .validate(&g, &cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", g.name));
        assert_eq!(r.tuned.plan.mapper_name, TUNED_MAPPER_NAME);
    }
}

#[test]
fn tuned_mapper_plans_validate_and_never_lose_via_mapper_api() {
    let cfg = small_cfg();
    let cache = std::sync::Arc::new(EvalCache::new());
    for g in zoo_tasks() {
        let tuned = PipeOrgan::default().tuned(std::sync::Arc::clone(&cache));
        let plan = tuned.plan(&g, &cfg);
        plan.validate(&g, &cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", g.name));
        let heur = evaluate(&g, &PipeOrgan::default().plan(&g, &cfg), &cfg);
        let got = evaluate(&g, &plan, &cfg);
        assert!(
            got.cycles <= heur.cycles * 1.0001,
            "{}: tuned mapper {} vs heuristic {}",
            g.name,
            got.cycles,
            heur.cycles
        );
    }
}

// ---- persistent cache: cold vs warm across "processes" ---------------------

#[test]
fn cache_file_warm_rerun_performs_strictly_fewer_evaluations() {
    let cfg = small_cfg();
    let dse = quick_dse();
    let g = workloads::keyword_detection();
    let path = std::env::temp_dir().join(format!(
        "pipeorgan_dse_warm_test_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    // Cold run: fresh cache, then persist it — the `pipeorgan dse
    // --cache-file` save path.
    let cold_cache = EvalCache::new();
    let cold = explore(&g, &cfg, &dse, &cold_cache, 1);
    assert!(cold.evaluations > 0, "cold run must evaluate candidates");
    cold_cache.save_file(&path).unwrap();

    // Warm run: a new cache hydrated from the file stands in for a second
    // process. It must do strictly fewer evaluations (in fact zero, the
    // same as an in-process rerun) and reach the same optimum.
    let (warm_cache, outcome) = EvalCache::load_file(&path);
    assert!(matches!(outcome, CacheLoadOutcome::Warm { entries } if entries > 0));
    let warm = explore(&g, &cfg, &dse, &warm_cache, 1);
    assert!(
        warm.evaluations < cold.evaluations,
        "warm rerun must evaluate strictly less: {} vs {}",
        warm.evaluations,
        cold.evaluations
    );
    assert_eq!(
        warm.evaluations, 0,
        "a file-hydrated cache must match an in-process rerun exactly"
    );
    assert!(warm.cache_hits > 0);
    assert_eq!(warm.best().cycles, cold.best().cycles);
    assert_eq!(warm.tuned.cycles, cold.tuned.cycles);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_cache_file_degrades_to_cold_start_not_panic() {
    let cfg = small_cfg();
    let dse = quick_dse();
    let g = workloads::keyword_detection();
    let path = std::env::temp_dir().join(format!(
        "pipeorgan_dse_corrupt_test_{}.json",
        std::process::id()
    ));
    std::fs::write(&path, "{\"version\": 1, \"entries\": [{\"trunc").unwrap();
    let (cache, outcome) = EvalCache::load_file(&path);
    assert!(matches!(outcome, CacheLoadOutcome::Rejected { .. }));
    // The run proceeds exactly like a cold start.
    let r = explore(&g, &cfg, &dse, &cache, 1);
    assert!(r.evaluations > 0);
    assert!(r.best().cycles <= r.heuristic.cycles * 1.0001);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tuned_mapper_warm_starts_from_cache_file() {
    let cfg = small_cfg();
    let g = workloads::gaze_estimation();
    let path = std::env::temp_dir().join(format!(
        "pipeorgan_tuned_warm_test_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    // Unbounded budget: a budget-truncated cold search could otherwise
    // legitimately differ from the warm (all-hits) replan.
    let cold_cache = std::sync::Arc::new(EvalCache::new());
    let cold_plan = TunedPipeOrgan::new(std::sync::Arc::clone(&cold_cache))
        .with_budget(u64::MAX)
        .plan(&g, &cfg);
    let cold_misses = cold_cache.stats().misses;
    assert!(cold_misses > 0);
    cold_cache.save_file(&path).unwrap();

    let (loaded, _) = EvalCache::load_file(&path);
    let warm_cache = std::sync::Arc::new(loaded);
    let warm_plan = TunedPipeOrgan::new(std::sync::Arc::clone(&warm_cache))
        .with_budget(u64::MAX)
        .plan(&g, &cfg);
    assert!(
        warm_cache.stats().misses < cold_misses,
        "file-hydrated planning must evaluate strictly less: {} vs {cold_misses}",
        warm_cache.stats().misses
    );
    assert_eq!(warm_plan, cold_plan, "warm planning must reach the same plan");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn memoization_makes_repeat_search_free() {
    let cfg = small_cfg();
    let dse = quick_dse();
    let g = workloads::keyword_detection();
    let cache = EvalCache::new();
    let cold = explore(&g, &cfg, &dse, &cache, 1);
    assert!(cold.evaluations > 0);
    let warm = explore(&g, &cfg, &dse, &cache, 1);
    assert_eq!(warm.evaluations, 0, "second identical sweep must be all hits");
    assert!(warm.cache_hits >= cold.evaluations);
    assert_eq!(warm.best().cycles, cold.best().cycles);
}

// ---- strict CLI flag policy for `dse` --------------------------------------

fn dse_flag_table() -> Vec<(&'static str, bool)> {
    let mut flags: Vec<(&'static str, bool)> = vec![
        ("out", true),
        ("workers", true),
        ("config", true),
        ("artifacts", true),
        ("seed", true),
    ];
    flags.extend_from_slice(DSE_FLAGS);
    flags
}

fn s(v: &[&str]) -> Vec<String> {
    v.iter().map(|x| x.to_string()).collect()
}

#[test]
fn dse_subcommand_accepts_its_flags() {
    let a = Args::parse(
        &s(&[
            "dse",
            "--workload",
            "keyword_detection",
            "--strategy",
            "beam",
            "--beam",
            "4",
            "--depth-cap",
            "3",
            "--workers",
            "2",
            "--out",
            "reports",
        ]),
        &dse_flag_table(),
    )
    .unwrap();
    assert_eq!(a.subcommand, "dse");
    assert_eq!(a.get("workload"), Some("keyword_detection"));
    let d = DseConfig::from_cli(&a).unwrap();
    assert_eq!(d.beam_width, 4);
    assert_eq!(d.depth_cap, 3);
}

#[test]
fn unknown_dse_flags_are_rejected() {
    // Typos on dse stay hard errors (the repo's strict-flag policy).
    for bad in [
        ["dse", "--bogus", "1"],
        ["dse", "--beamwidth", "4"],
        ["dse", "--workloads", "all"], // the flag is singular
    ] {
        assert!(
            Args::parse(&s(&bad), &dse_flag_table()).is_err(),
            "{bad:?} should be rejected"
        );
    }
    // And dse-only flags stay rejected on other subcommands, which use the
    // base table without DSE_FLAGS.
    let base: &[(&str, bool)] = &[("out", true), ("workers", true)];
    assert!(Args::parse(&s(&["e2e", "--beam", "4"]), base).is_err());
    assert!(Args::parse(&s(&["e2e", "--workload", "x"]), base).is_err());
}

// ---- enumeration invariants the DSE relies on ------------------------------

#[test]
fn granularity_clamp_never_drops_below_per_pe_floor() {
    // Every handoff the enumerator builds routes at least one word per
    // producer PE per interval, and words × intervals always covers the
    // tensor.
    pipeorgan::util::proptest_lite::run(200, |rng| {
        let total = rng.gen_usize(1, 1 << 20) as u64;
        let base_words = rng.gen_usize(1, (total as usize) * 2) as u64;
        let producer_pes = rng.gen_usize(1, 1025);
        let (words, intervals) = clamp_granularity(total, base_words, producer_pes);
        let floor = (producer_pes as u64).min(total);
        if words < floor {
            return Err(format!(
                "words {words} below floor {floor} (total {total}, pes {producer_pes})"
            ));
        }
        if words > total {
            return Err(format!("words {words} exceeds tensor {total}"));
        }
        if words * intervals < total {
            return Err(format!(
                "coverage hole: {words} × {intervals} < {total}"
            ));
        }
        Ok(())
    });
}

#[test]
fn enumerated_candidates_respect_granularity_floor() {
    let cfg = small_cfg();
    let g = workloads::gaze_estimation();
    for start in 0..g.num_layers() {
        for d in legal_depths(&g, &cfg, start, 4) {
            let seg = Segment::new(start, d);
            for cand in segment_candidates(&g, &cfg, &seg, 3) {
                for h in &cand.planned.handoffs {
                    let total = g.layer(seg.start + h.from_stage).output_act_words();
                    let floor =
                        (cand.planned.pe_alloc[h.from_stage].max(1) as u64).min(total.max(1));
                    assert!(
                        h.words_per_interval >= floor,
                        "segment [{start},{d}) handoff below per-PE floor"
                    );
                }
            }
        }
    }
}

#[test]
fn organization_candidates_cover_every_legal_depth() {
    let cfg = small_cfg();
    for depth in 1..=cfg.max_pipeline_depth() {
        let orgs = organization_candidates(depth);
        assert!(!orgs.is_empty(), "no candidates at depth {depth}");
        if depth == 1 {
            assert_eq!(orgs, vec![Organization::Sequential]);
            continue;
        }
        // Whatever granularity the chooser sees, its pick must be inside
        // the oracle candidate list the DSE enumerates.
        for gran in [1u64, 64, 4096, 262_144, 1 << 22] {
            let choice = choose_organization(&cfg, depth, gran, cfg.num_pes() / depth.max(1));
            assert!(
                orgs.contains(&choice.organization),
                "depth {depth} gran {gran}: chooser picked {:?} outside candidates {orgs:?}",
                choice.organization
            );
        }
        // Every candidate builds a valid placement at this depth.
        let shares = vec![cfg.num_pes() / depth.max(1); depth];
        for org in orgs {
            Placement::build(cfg.pe_rows, cfg.pe_cols, org, &shares)
                .validate()
                .unwrap_or_else(|e| panic!("depth {depth} org {org:?}: {e}"));
        }
    }
}

#[test]
fn ladder_matches_algorithm1_finest_at_scale_one() {
    // Scale-1 candidates carry exactly the Algorithm-1 finest granularity
    // after the per-PE clamp — the heuristic mapper's own choice.
    let cfg = small_cfg();
    let g = workloads::keyword_detection();
    let seg = Segment::new(0, 2);
    let styles: Vec<_> = (0..2).map(|i| choose_dataflow(g.layer(i))).collect();
    let nests: Vec<LoopNest> = (0..2)
        .map(|i| LoopNest::for_op(&g.layer(i).op, styles[i]))
        .collect();
    let total = g.layer(0).output_act_words();
    let finest = pair_granularity(&nests[0], &nests[1], total);
    for cand in segment_candidates(&g, &cfg, &seg, 1) {
        assert_eq!(cand.gran_scale, 1);
        let adj = cand
            .planned
            .handoffs
            .iter()
            .find(|h| !h.is_skip && h.from_stage == 0)
            .expect("depth-2 segment has a 0→1 handoff");
        let (words, intervals) =
            clamp_granularity(total, finest.words, cand.planned.pe_alloc[0]);
        assert_eq!(adj.words_per_interval, words);
        assert_eq!(adj.intervals, intervals);
    }
}
