//! Integration tests over the whole mapping→evaluation stack: every mapper
//! on every zoo task, plus the headline Fig. 13/14 shape assertions and
//! cross-mapper invariants.

use pipeorgan::baselines::{SimbaLike, TangramLike};
use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::cost::{evaluate, Mapper};
use pipeorgan::mapper::PipeOrgan;
use pipeorgan::util::stats::geomean;
use pipeorgan::workloads;

fn cfg() -> ArchConfig {
    ArchConfig::default()
}

#[test]
fn every_mapper_produces_valid_plans_on_every_task() {
    let c = cfg();
    let mappers: Vec<Box<dyn Mapper>> = vec![
        Box::new(PipeOrgan::default()),
        Box::new(PipeOrgan::on_mesh()),
        Box::new(TangramLike),
        Box::new(SimbaLike),
    ];
    for g in workloads::all_tasks() {
        for m in &mappers {
            let plan = m.plan(&g, &c);
            plan.validate(&g, &c)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", m.name(), g.name));
            let cost = evaluate(&g, &plan, &c);
            assert!(cost.cycles.is_finite() && cost.cycles > 0.0);
            assert!(cost.dram_words > 0);
            assert!(cost.energy > 0.0);
        }
    }
}

#[test]
fn fig13_shape_pipeorgan_wins_geomean() {
    // The reproduction target: PipeOrgan ≥ both baselines in geomean, with
    // the biggest wins on activation-heavy tasks (paper: 1.95x; our
    // simulator constants land lower but the ordering must hold).
    let c = cfg();
    let mut vs_tangram = Vec::new();
    let mut vs_simba = Vec::new();
    for g in workloads::all_tasks() {
        let po = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c).cycles;
        let tg = evaluate(&g, &TangramLike.plan(&g, &c), &c).cycles;
        let sb = evaluate(&g, &SimbaLike.plan(&g, &c), &c).cycles;
        vs_tangram.push(tg / po);
        vs_simba.push(sb / po);
    }
    let gm_t = geomean(&vs_tangram);
    let gm_s = geomean(&vs_simba);
    assert!(gm_t > 1.1, "geomean vs TANGRAM-like = {gm_t}");
    assert!(gm_s > 1.5, "geomean vs SIMBA-like = {gm_s}");
    // No task should regress badly under PipeOrgan.
    assert!(
        vs_tangram.iter().all(|&x| x > 0.85),
        "regression: {vs_tangram:?}"
    );
}

#[test]
fn fig14_shape_dram_reduction() {
    // DRAM accesses drop vs TANGRAM-like (paper: 31% geomean reduction).
    let c = cfg();
    let mut ratios = Vec::new();
    for g in workloads::all_tasks() {
        let po = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c).dram_words;
        let tg = evaluate(&g, &TangramLike.plan(&g, &c), &c).dram_words;
        ratios.push(po as f64 / tg as f64);
    }
    let gm = geomean(&ratios);
    assert!(gm < 0.8, "geomean DRAM ratio = {gm}");
    assert!(ratios.iter().all(|&r| r < 1.3), "{ratios:?}");
}

#[test]
fn amp_never_hurts_pipeorgan() {
    let c = cfg();
    for g in workloads::all_tasks() {
        let amp = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c).cycles;
        let mesh = evaluate(&g, &PipeOrgan::on_mesh().plan(&g, &c), &c).cycles;
        assert!(
            amp <= mesh * 1.001,
            "{}: AMP {amp} vs mesh {mesh}",
            g.name
        );
    }
}

#[test]
fn weight_heavy_tasks_show_no_pipelining_benefit() {
    // Fig. 13 discussion: "Action segmentation and hand tracking are
    // mostly weight heavy, and therefore do not favor pipelining" — the
    // PipeOrgan advantage there must be small.
    let c = cfg();
    for g in [workloads::action_segmentation()] {
        let po = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c).cycles;
        let tg = evaluate(&g, &TangramLike.plan(&g, &c), &c).cycles;
        let speedup = tg / po;
        assert!(
            (0.9..1.6).contains(&speedup),
            "{}: unexpected speedup {speedup}",
            g.name
        );
    }
}

#[test]
fn smaller_array_still_works() {
    // Config system: a 16x16 array (quarter substrate) evaluates cleanly.
    let c = ArchConfig::from_kv_text("pe_rows = 16\npe_cols = 16").unwrap();
    let g = workloads::keyword_detection();
    for m in [PipeOrgan::default().plan(&g, &c), TangramLike.plan(&g, &c)] {
        m.validate(&g, &c).unwrap();
        let cost = evaluate(&g, &m, &c);
        assert!(cost.cycles > 0.0);
    }
}

#[test]
fn torus_and_fb_topologies_evaluate() {
    // Ablation topologies run end to end.
    let c = cfg();
    let g = workloads::gaze_estimation();
    for topo in [TopologyKind::Torus, TopologyKind::FlattenedButterfly] {
        let cost = evaluate(&g, &PipeOrgan::on(topo).plan(&g, &c), &c);
        assert!(cost.cycles > 0.0);
    }
}
