//! Failure injection: the runtime and config layers must fail *cleanly*
//! (typed errors, no panics) on corrupt artifacts, truncated manifests,
//! bad configs, and malformed plans.

use pipeorgan::config::ArchConfig;
use pipeorgan::runtime::{Manifest, Runtime};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("pipeorgan_fail_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_a_clean_error() {
    let d = tmpdir("nomanifest");
    let rt = Runtime::new(&d).unwrap();
    let err = rt.manifest().unwrap_err();
    assert!(format!("{err:#}").contains("manifest.json"), "{err:#}");
}

#[test]
fn truncated_manifest_is_a_clean_error() {
    let d = tmpdir("truncated");
    std::fs::write(d.join("manifest.json"), r#"{"segment": {"h": 32"#).unwrap();
    let rt = Runtime::new(&d).unwrap();
    assert!(rt.manifest().is_err());
}

#[test]
fn manifest_missing_programs_key() {
    let d = tmpdir("noprog");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"segment": {"h":8,"w":8,"c_in":1,"c_mid":1,"c_out":1,"band":4,"r":3,"s":3}}"#,
    )
    .unwrap();
    let rt = Runtime::new(&d).unwrap();
    let err = rt.manifest().unwrap_err();
    assert!(format!("{err:#}").contains("programs"));
}

#[test]
fn unknown_program_name_is_a_clean_error() {
    let d = tmpdir("unknownprog");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"segment": {"h":8,"w":8,"c_in":1,"c_mid":1,"c_out":1,"band":4,"r":3,"s":3},
            "programs": {}}"#,
    )
    .unwrap();
    let rt = Runtime::new(&d).unwrap();
    let err = match rt.load_program("nope") {
        Err(e) => e,
        Ok(_) => panic!("unknown program should not load"),
    };
    assert!(format!("{err:#}").contains("nope"));
}

#[test]
fn corrupt_hlo_text_is_a_clean_error() {
    let d = tmpdir("corrupt");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"segment": {"h":8,"w":8,"c_in":1,"c_mid":1,"c_out":1,"band":4,"r":3,"s":3},
            "programs": {"bad": {"file": "bad.hlo.txt",
                                  "inputs": [{"shape": [2,2], "dtype": "f32"}],
                                  "output": {"shape": [2,2], "dtype": "f32"},
                                  "role": "corrupt"}}}"#,
    )
    .unwrap();
    std::fs::write(d.join("bad.hlo.txt"), "this is not an HLO module").unwrap();
    let rt = Runtime::new(&d).unwrap();
    assert!(rt.load_program("bad").is_err());
}

#[test]
fn manifest_parse_rejects_nonsense_shapes() {
    let text = r#"{"segment": {"h":8,"w":8,"c_in":1,"c_mid":1,"c_out":1,"band":4,"r":3,"s":3},
        "programs": {"p": {"file": "p.hlo.txt",
                            "inputs": [{"shape": "wat", "dtype": "f32"}],
                            "output": {"shape": [1], "dtype": "f32"},
                            "role": ""}}}"#;
    assert!(Manifest::parse(text).is_err());
}

#[test]
fn config_failures_are_typed() {
    for bad in [
        "pe_rows = 0",
        "pe_rows = -3",
        "topology = ring",
        "dram_bytes_per_cycle = 0",
        "mystery_knob = 7",
        "pe_rows",
    ] {
        assert!(
            ArchConfig::from_kv_text(bad).is_err(),
            "accepted bad config: {bad}"
        );
    }
}

#[test]
fn plan_validation_catches_malformed_plans() {
    use pipeorgan::config::TopologyKind;
    use pipeorgan::cost::{MappingPlan, PlannedHandoff, PlannedSegment};
    use pipeorgan::dataflow::DataflowStyle;
    use pipeorgan::pipeline::Segment;
    use pipeorgan::spatial::Organization;

    let g = pipeorgan::workloads::synthetic::equal_conv_segment(2);
    let cfg = ArchConfig::default();
    // handoff pointing backwards
    let plan = MappingPlan {
        mapper_name: "bad".into(),
        topology: TopologyKind::Mesh,
        segments: vec![PlannedSegment {
            segment: Segment::new(0, 2),
            organization: Organization::Blocked1D,
            pe_alloc: vec![512, 512],
            styles: vec![DataflowStyle::OutputStationary; 2],
            handoffs: vec![PlannedHandoff {
                from_stage: 1,
                to_stage: 0,
                words_per_interval: 1,
                intervals: 1,
                via_gb: false,
                is_skip: false,
            }],
        }],
    };
    assert!(plan.validate(&g, &cfg).is_err());
}
