//! Integration tests for the fleet layer and the event-core split it
//! rides on: the single-array pipeline (`push_arrivals` + `drive` +
//! `finish`) reproduces `simulate` bit for bit, load-aware routers never
//! miss more than round-robin on any canned scenario under the diurnal
//! curve (the acceptance criterion), fleet metrics are bit-identical
//! across planner worker counts and across reruns, accounting closes at
//! the fleet level, and the autoscaler/admission controller behave under
//! overload.

use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{canned_scenarios, scenario_by_name, CoschedConfig};
use pipeorgan::dse::EvalCache;
use pipeorgan::obs::Obs;
use pipeorgan::serve::{
    drive, plan_scenario, push_arrivals, run_fleet_scenario, simulate, simulate_fleet,
    AdmissionPolicy, ArrayModel, ArrivalProcess, AutoscaleConfig, BandwidthModel, EventCore,
    FleetConfig, Policy, RouterPolicy, ServeConfig, ServePlan, SimOptions,
};

fn small_cfg() -> ArchConfig {
    ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    }
}

const DIURNAL: ArrivalProcess = ArrivalProcess::Diurnal {
    period_s: 0.0,
    amp: 0.8,
};

fn identical_plans(
    sc: &pipeorgan::cosched::Scenario,
    cfg: &ArchConfig,
    cache: &EvalCache,
    n: usize,
) -> Vec<ServePlan> {
    (0..n)
        .map(|_| plan_scenario(sc, cfg, &CoschedConfig::default(), cache, 2).unwrap())
        .collect()
}

/// The API-split regression gate: driving a fresh [`ArrayModel`] through
/// the shared event core by hand must reproduce [`simulate`] bit for bit
/// — trace, metrics, and span — for every policy.
#[test]
fn single_array_run_is_bit_identical_through_the_event_core() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    let arrivals = pipeorgan::serve::streams(&sc, &ArrivalProcess::Poisson, 2.0, 0.05, 7);
    for &policy in Policy::ALL.iter() {
        let reference = simulate(&sc, &plan, policy, &arrivals, SimOptions::default());
        let obs = Obs::disabled();
        let mut events = EventCore::new();
        push_arrivals(&mut events, &plan, &arrivals);
        let mut model = ArrayModel::new(&sc, &plan, policy, SimOptions::default(), &obs);
        let last_s = drive(&mut model, &mut events);
        let manual = model.finish(last_s.max(1e-12));
        assert_eq!(manual.trace, reference.trace, "{}", policy.name());
        assert_eq!(manual.tasks, reference.tasks, "{}", policy.name());
        assert_eq!(manual.span_s, reference.span_s, "{}", policy.name());
    }
}

/// The acceptance criterion: on every canned scenario, at the same
/// diurnal arrival replay over identical chips, the load-aware routers
/// (JSQ, and affinity which spills to JSQ under backlog) never miss more
/// than blind round-robin. With the static bandwidth split and per-task
/// home regions, service times are constant per (chip, task), so routing
/// to the least-backlogged chip keeps every queue pointwise no longer
/// than round-robin's — the miss set can only shrink.
#[test]
fn jsq_and_affinity_never_worse_than_round_robin_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let fc = FleetConfig {
        chips: 3,
        routers: RouterPolicy::ALL.to_vec(),
        ..FleetConfig::default()
    };
    let opts = SimOptions {
        bandwidth: BandwidthModel::Static,
        ..SimOptions::default()
    };
    let obs = Obs::disabled();
    for sc in canned_scenarios() {
        let plans = identical_plans(&sc, &cfg, &cache, fc.chips);
        for mult in [1.0, 8.0] {
            let arrivals = pipeorgan::serve::streams(&sc, &DIURNAL, mult, 0.05, 0);
            let run = |router| {
                simulate_fleet(&sc, &plans, Policy::Fifo, router, &fc, opts, &arrivals, &obs)
            };
            let rr = run(RouterPolicy::RoundRobin);
            for router in [RouterPolicy::Jsq, RouterPolicy::Affinity] {
                let out = run(router);
                assert!(
                    out.miss_rate() <= rr.miss_rate() + 1e-12,
                    "{} @ {mult}x: {} miss rate {} > round-robin {}",
                    sc.name,
                    router.name(),
                    out.miss_rate(),
                    rr.miss_rate()
                );
            }
        }
    }
}

/// Planner worker counts parallelize the search without changing its
/// result, and the serving replay downstream is a pure function of the
/// plan — so the whole fleet study is bit-identical across 1/2/4 workers
/// and across reruns at the same seed.
#[test]
fn fleet_metrics_bit_identical_across_worker_counts_and_reruns() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let sv = ServeConfig {
        policies: vec![Policy::Edf],
        arrivals: DIURNAL,
        duration_s: 0.05,
        rate_mult: 2.0,
        seed: 11,
        ..ServeConfig::default()
    };
    let fc = FleetConfig {
        chips: 2,
        routers: vec![RouterPolicy::Jsq],
        ..FleetConfig::default()
    };
    let runs: Vec<_> = [1usize, 2, 4, 2]
        .iter()
        .map(|&w| run_fleet_scenario(&sc, &cfg, &sv, &fc, &[], &cache, w).unwrap())
        .collect();
    let base = &runs[0].outcomes[0];
    assert!(base.total_requests() > 0);
    for run in &runs[1..] {
        let o = &run.outcomes[0];
        assert_eq!(o.tasks, base.tasks);
        assert_eq!(o.chips, base.chips);
        assert_eq!(o.span_s, base.span_s);
        assert_eq!(o.rejected, base.rejected);
        assert_eq!(o.cost_pe_s_per_m, base.cost_pe_s_per_m);
    }
}

/// Fleet-level accounting closes on every canned scenario and router:
/// everything that arrived was completed, dropped, or rejected at the
/// front door, and per-chip routed counts sum to the admitted total.
#[test]
fn fleet_accounting_closes_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sv = ServeConfig {
        policies: vec![Policy::Fifo],
        arrivals: DIURNAL,
        duration_s: 0.05,
        rate_mult: 4.0,
        seed: 3,
        ..ServeConfig::default()
    };
    let fc = FleetConfig {
        chips: 3,
        routers: RouterPolicy::ALL.to_vec(),
        ..FleetConfig::default()
    };
    for sc in canned_scenarios() {
        let run = run_fleet_scenario(&sc, &cfg, &sv, &fc, &[], &cache, 2).unwrap();
        assert_eq!(run.outcomes.len(), RouterPolicy::ALL.len());
        assert_eq!(run.plans.len(), fc.chips);
        for o in &run.outcomes {
            let arrived = o.total_requests();
            let served: u64 = o.tasks.iter().map(|m| m.completed + m.dropped).sum();
            assert_eq!(
                served + o.rejected,
                arrived,
                "{} {}: accounting leak",
                sc.name,
                o.router.name()
            );
            let routed: u64 = o.chips.iter().map(|c| c.routed).sum();
            assert_eq!(routed + o.rejected, arrived);
            assert_eq!(o.chips.len(), fc.chips);
            for c in &o.chips {
                assert!(c.up_s <= o.span_s + 1e-9, "{}: chip {} up too long", sc.name, c.chip);
            }
            assert!(o.cost_pe_s_per_m > 0.0);
        }
    }
}

/// Under heavy overload with deadline admission and the autoscaler armed,
/// the front door sheds load it provably cannot serve (every rejection is
/// also counted as a miss) and chip up-time never exceeds the span; a
/// heterogeneous chip list must produce chips of different sizes.
#[test]
fn admission_autoscale_and_heterogeneous_chips_under_overload() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let sv = ServeConfig {
        policies: vec![Policy::Edf],
        arrivals: DIURNAL,
        duration_s: 0.05,
        rate_mult: 64.0,
        seed: 5,
        ..ServeConfig::default()
    };
    let fc = FleetConfig {
        chips: 3,
        routers: vec![RouterPolicy::Jsq],
        admission: AdmissionPolicy::Deadline,
        autoscale: Some(AutoscaleConfig::default()),
        ..FleetConfig::default()
    };
    let dims = [(16usize, 16usize), (16, 8)];
    let run = run_fleet_scenario(&sc, &cfg, &sv, &fc, &dims, &cache, 2).unwrap();
    let o = &run.outcomes[0];
    assert!(o.rejected > 0, "64x overload must trip deadline admission");
    assert!(o.total_missed() >= o.rejected, "rejections count as misses");
    for c in &o.chips {
        assert!(c.up_s <= o.span_s + 1e-9);
    }
    // Dims cycle across chips: 0 and 2 are full arrays, 1 is half-width.
    let pes: Vec<usize> = o.chips.iter().map(|c| c.pes).collect();
    assert_eq!(pes[0], pes[2]);
    assert!(pes[1] < pes[0], "chip 1 should be the 16x8 instance: {pes:?}");
}
