//! Integration tests for the link-level NoC telemetry layer: the loadmap
//! max equals the scalar worst-channel-load bit-exactly on every zoo
//! workload × topology kind and on every canned cosched scenario, and the
//! emitted `pipeorgan-noc-v1` artifacts satisfy the same structural
//! checks `tools/trace_check.py` enforces.

use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::cosched::{canned_scenarios, region_config, CoschedConfig};
use pipeorgan::cost::{evaluate, plan_loadmap, segment_loadmap, Mapper};
use pipeorgan::dse::EvalCache;
use pipeorgan::mapper::PipeOrgan;
use pipeorgan::noc::Topology;
use pipeorgan::report;
use pipeorgan::util::json::Json;
use pipeorgan::workloads;

const ALL_KINDS: [TopologyKind; 4] = [
    TopologyKind::Mesh,
    TopologyKind::Amp,
    TopologyKind::Torus,
    TopologyKind::FlattenedButterfly,
];

/// Every zoo workload, every topology kind: the merged plan loadmap's max
/// is exactly the `f64::max` fold of the per-segment scalars the cost
/// model reports — the same equality `report::noc` pins into artifacts.
#[test]
fn plan_loadmap_max_matches_scalar_on_every_zoo_workload_and_topology() {
    for kind in ALL_KINDS {
        let cfg = ArchConfig {
            topology: kind,
            ..ArchConfig::default()
        };
        for g in workloads::all_tasks() {
            let plan = PipeOrgan::default().plan(&g, &cfg);
            let cost = evaluate(&g, &plan, &cfg);
            let scalar = cost
                .per_segment
                .iter()
                .map(|s| s.worst_channel_load_per_interval)
                .fold(0.0, f64::max);
            let map = plan_loadmap(&g, &plan, &cfg);
            assert_eq!(map.max(), scalar, "{} on {}", g.name, kind.name());
            assert_eq!(
                (map.topology().rows, map.topology().cols),
                (cfg.pe_rows, cfg.pe_cols)
            );
        }
    }
}

/// Every canned cosched scenario: each assignment's reported
/// `worst_channel_load` equals the max of its region-local loadmap,
/// re-derived segment by segment from the retained plan.
#[test]
fn cosched_assignment_scalars_match_region_loadmaps() {
    let cfg = ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    };
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let r = pipeorgan::cosched::schedule(&sc, &cfg, &CoschedConfig::default(), &cache, 2)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        for a in &r.cosched.assignments {
            let spec = sc.tasks.iter().find(|t| t.name() == a.task).unwrap();
            let mut rcfg = region_config(&cfg, &a.region);
            rcfg.topology = a.topology;
            let topo = Topology::cached(a.plan.topology, rcfg.pe_rows, rcfg.pe_cols);
            let mut max = 0.0f64;
            for seg in &a.plan.segments {
                max = max.max(segment_loadmap(&spec.graph, seg, &rcfg, &topo).max());
            }
            assert_eq!(
                max, a.worst_channel_load,
                "{}/{} on {}",
                sc.name,
                a.task,
                a.topology.name()
            );
        }
    }
}

/// Structural checks mirroring `tools/trace_check.py check_noc_report`:
/// schema tag, four direction grids of exactly `rows × cols` cells,
/// finite non-negative loads, grid max == entry max == scalar (when
/// present), ordered distribution stats, and regions covering the grid.
fn assert_noc_document(doc: &Json, source: &str) {
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("pipeorgan-noc-v1")
    );
    assert_eq!(doc.get("source").and_then(|s| s.as_str()), Some(source));
    assert!(doc
        .get("link_words_per_cycle")
        .and_then(|v| v.as_f64())
        .is_some());
    let entries = doc.get("entries").and_then(|e| e.as_arr()).unwrap();
    assert!(!entries.is_empty(), "{source}: no entries");
    for e in entries {
        let label = e.get("label").and_then(|l| l.as_str()).unwrap();
        let rows = e.get("rows").and_then(|v| v.as_f64()).unwrap() as usize;
        let cols = e.get("cols").and_then(|v| v.as_f64()).unwrap() as usize;
        let mut grid_max = 0.0f64;
        for dir in ["east", "west", "north", "south"] {
            let cells = e
                .get("grid")
                .and_then(|g| g.get(dir))
                .and_then(|a| a.as_arr())
                .unwrap_or_else(|| panic!("{label}: missing {dir} grid"));
            assert_eq!(cells.len(), rows * cols, "{label}: {dir} grid shape");
            for c in cells {
                let w = c.as_f64().unwrap();
                assert!(w.is_finite() && w >= 0.0, "{label}: bad cell {w}");
                grid_max = grid_max.max(w);
            }
        }
        let max = e.get("max").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(grid_max, max, "{label}: grid max vs reported max");
        if let Some(scalar) = e.get("worst_channel_load").and_then(|v| v.as_f64()) {
            assert_eq!(max, scalar, "{label}: map max vs cost scalar");
        }
        let p50 = e.get("p50").and_then(|v| v.as_f64()).unwrap();
        let p95 = e.get("p95").and_then(|v| v.as_f64()).unwrap();
        assert!(p50 <= p95 && p95 <= max, "{label}: unordered stats");
        assert!(e.get("verify").and_then(|v| v.get("congestion_free")).is_some());
        for region in e.get("regions").and_then(|r| r.as_arr()).unwrap() {
            let r0 = region.get("row0").and_then(|v| v.as_f64()).unwrap() as usize;
            let c0 = region.get("col0").and_then(|v| v.as_f64()).unwrap() as usize;
            let rr = region.get("rows").and_then(|v| v.as_f64()).unwrap() as usize;
            let rc = region.get("cols").and_then(|v| v.as_f64()).unwrap() as usize;
            assert!(r0 + rr <= rows && c0 + rc <= cols, "{label}: region out of grid");
        }
    }
}

/// The three emitters produce schema-valid `pipeorgan-noc-v1` documents
/// end to end (the same JSON `--noc-out` writes), on an XR scenario.
#[test]
fn noc_artifacts_from_all_three_subcommands_validate() {
    let cfg = ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    };
    let cache = EvalCache::new();

    let g = pipeorgan::workloads::synthetic::pointwise_conv_segment(3);
    let dse = pipeorgan::dse::explore(&g, &cfg, &Default::default(), &cache, 1);
    let rep = report::dse_noc_report(&cfg, &[g], &[dse]);
    assert_noc_document(&rep.json, "dse");

    let sc = pipeorgan::cosched::scenario_by_name("xr-core").unwrap();
    let cos = pipeorgan::cosched::schedule(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    let rep = report::cosched_noc_report(&cfg, std::slice::from_ref(&sc), &[cos]);
    assert_noc_document(&rep.json, "cosched");

    let sv = pipeorgan::serve::ServeConfig {
        duration_s: 0.05,
        ..Default::default()
    };
    let run = pipeorgan::serve::run_scenario(&sc, &cfg, &sv, &cache, 1).unwrap();
    let rep = report::serve_noc_report(&cfg, &[sc], &[run], &sv.obs);
    assert_noc_document(&rep.json, "serve");

    // The artifact round-trips through the JSON text path `--noc-out`
    // uses (`to_pretty` → parse).
    let reparsed = Json::parse(&rep.json.to_pretty()).unwrap();
    assert_noc_document(&reparsed, "serve");
}
