//! Integration tests for the observability layer end to end: same-seed
//! serve runs replay an identical sim-domain event sequence, the Perfetto
//! export obeys the trace_event schema (every event carries
//! `ph`/`ts`/`pid`/`tid`, counter tracks sample monotonically, one named
//! track per region), a disabled handle records nothing across a full
//! simulation, and the `report::obs` artifact round-trips through the
//! JSON parser.

use std::collections::{BTreeMap, HashSet};

use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{scenario_by_name, CoschedConfig, Scenario};
use pipeorgan::dse::EvalCache;
use pipeorgan::obs::{Obs, PID_PLAN, PID_SIM};
use pipeorgan::report::obs_report;
use pipeorgan::serve::{
    plan_scenario, simulate, simulate_traced, streams, ArrivalProcess, Policy, ServePlan,
    SimOptions,
};
use pipeorgan::util::json::Json;

/// One planned canned scenario with a fixed-seed Poisson replay: the
/// shared fixture for every test here. Small array + short window keep
/// debug-build runs fast.
fn planned_xr_core() -> (Scenario, ServePlan, Vec<Vec<f64>>) {
    let cfg = ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    };
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").expect("canned scenario");
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2)
        .expect("planning succeeds");
    let arrivals = streams(&sc, &ArrivalProcess::Poisson, 1.0, 0.1, 7);
    assert!(
        arrivals.iter().any(|s| !s.is_empty()),
        "fixture window must carry traffic"
    );
    (sc, plan, arrivals)
}

#[test]
fn same_seed_replays_an_identical_sim_event_sequence() {
    let (sc, plan, arrivals) = planned_xr_core();
    let run = || {
        let obs = Obs::enabled();
        simulate_traced(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default(), &obs);
        // Sim-domain events only: wall-domain timings are real and are
        // not expected to replay.
        obs.events()
            .into_iter()
            .filter(|e| (PID_SIM..PID_PLAN).contains(&e.pid))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert!(!a.is_empty(), "instrumented run records sim events");
    assert_eq!(a, b, "sim-domain trace must replay bit-identically");
}

#[test]
fn perfetto_export_obeys_the_trace_event_schema() {
    let (sc, plan, arrivals) = planned_xr_core();
    let obs = Obs::enabled();
    simulate_traced(&sc, &plan, Policy::Fifo, &arrivals, SimOptions::default(), &obs);
    let doc = obs.trace_json();
    let evs = doc
        .get("traceEvents")
        .and_then(|a| a.as_arr())
        .expect("traceEvents array");
    assert!(!evs.is_empty());
    for e in evs {
        for key in ["ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_some(), "missing {key} in {e}");
        }
    }

    // Counter tracks sample monotonically in time, per (pid, name).
    let mut last: BTreeMap<(u64, String), f64> = BTreeMap::new();
    for e in evs {
        if e.get("ph").and_then(|p| p.as_str()) != Some("C") {
            continue;
        }
        let pid = e.get("pid").and_then(|p| p.as_f64()).unwrap() as u64;
        let name = e.get("name").and_then(|n| n.as_str()).unwrap().to_string();
        let ts = e.get("ts").and_then(|t| t.as_f64()).unwrap();
        if let Some(prev) = last.insert((pid, name.clone()), ts) {
            assert!(ts >= prev, "counter {name} went back in time: {prev} -> {ts}");
        }
    }

    // The timeline view's counter tracks are all present.
    let counters: HashSet<&str> = evs
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("C"))
        .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
        .collect();
    for want in ["queue_depth", "dram_bw", "region_util", "worst_channel_load"] {
        assert!(counters.contains(want), "missing counter track {want}: {counters:?}");
    }

    // One named track per region.
    let thread_names = evs
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
        .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
        .count();
    assert!(
        thread_names >= sc.tasks.len(),
        "{thread_names} named tracks for {} regions",
        sc.tasks.len()
    );
}

#[test]
fn a_disabled_handle_records_nothing_across_a_full_simulation() {
    let (sc, plan, arrivals) = planned_xr_core();
    let obs = Obs::disabled();
    let traced = simulate_traced(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default(), &obs);
    assert!(traced.total_requests() > 0);
    assert!(obs.is_silent());
    assert!(obs.events().is_empty());
    assert_eq!(obs.counters_json(), Json::Null);
    // And instrumentation changes nothing about the simulation itself.
    let plain = simulate(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default());
    assert_eq!(plain.total_requests(), traced.total_requests());
    assert_eq!(plain.total_missed(), traced.total_missed());
}

#[test]
fn obs_report_round_trips_through_the_json_parser() {
    let (sc, plan, arrivals) = planned_xr_core();
    let obs = Obs::enabled();
    obs.timed("serve.simulate.edf", || {
        simulate_traced(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default(), &obs)
    });
    let r = obs_report(&obs).expect("instrumented run reports");
    assert_eq!(r.name, "obs");
    assert!(!r.table.rows.is_empty());
    let counters = r.json.get("counters").expect("counters key");
    assert!(counters.get("serve.edf.epochs").is_some());
    assert!(counters.get("time.serve.simulate.edf").is_some());
    let reparsed = Json::parse(&r.json.to_pretty()).unwrap();
    assert_eq!(reparsed, r.json);
}
