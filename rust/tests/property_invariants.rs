//! Cross-module property tests (proptest-lite): invariants of the full
//! mapping→evaluation stack over randomly generated models, placements and
//! configurations.

use pipeorgan::baselines::{SimbaLike, TangramLike};
use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::cosched::{CutAxis, CutTree};
use pipeorgan::cost::{evaluate, Mapper};
use pipeorgan::mapper::PipeOrgan;
use pipeorgan::prop_assert;
use pipeorgan::spatial::{allocate_pes, Organization, Placement};
use pipeorgan::util::proptest_lite;
use pipeorgan::util::rng::SplitMix64;
use pipeorgan::workloads::synthetic::random_model;

#[test]
fn mappers_produce_valid_costed_plans_on_random_models() {
    proptest_lite::run(60, |rng| {
        let g = random_model(rng, 16);
        let cfg = ArchConfig::default();
        for mapper in [0, 1, 2] {
            let plan = match mapper {
                0 => PipeOrgan::default().plan(&g, &cfg),
                1 => TangramLike.plan(&g, &cfg),
                _ => SimbaLike.plan(&g, &cfg),
            };
            if let Err(e) = plan.validate(&g, &cfg) {
                return Err(format!("{} on {}: {e}", plan.mapper_name, g.name));
            }
            let cost = evaluate(&g, &plan, &cfg);
            prop_assert!(
                cost.cycles.is_finite() && cost.cycles > 0.0,
                "{}: bad cycles {}",
                plan.mapper_name,
                cost.cycles
            );
            prop_assert!(cost.energy.is_finite() && cost.energy > 0.0);
            // A mapped model can never beat its pure-compute lower bound.
            let lower = g.total_macs() as f64 / cfg.peak_macs_per_cycle() as f64;
            prop_assert!(
                cost.cycles >= lower * 0.999,
                "{}: {} below compute bound {lower}",
                plan.mapper_name,
                cost.cycles
            );
        }
        Ok(())
    });
}

#[test]
fn placements_partition_the_array() {
    proptest_lite::run(200, |rng| {
        let rows = rng.gen_usize(2, 33);
        let cols = rng.gen_usize(2, 33);
        let stages = rng.gen_usize(1, 6.min(cols + 1));
        let shares: Vec<usize> = (0..stages).map(|_| rng.gen_usize(1, 10)).collect();
        let org = *rng.choose(&[
            Organization::Blocked1D,
            Organization::FineStriped1D,
            Organization::Blocked2D,
            Organization::Checkerboard2D,
        ]);
        if org == Organization::Blocked1D && cols < stages {
            return Ok(()); // cannot band fewer columns than stages
        }
        let p = Placement::build(rows, cols, org, &shares);
        if let Err(e) = p.validate() {
            return Err(format!("{org:?} {rows}x{cols} {shares:?}: {e}"));
        }
        // every PE belongs to at most one stage; totals sum to array size
        let total: usize = (0..stages).map(|s| p.stage_size(s)).sum();
        prop_assert!(
            total + p.idle_pes() == rows * cols,
            "{org:?}: coverage {total} + idle {} != {}",
            p.idle_pes(),
            rows * cols
        );
        Ok(())
    });
}

#[test]
fn allocation_is_exact_and_monotone() {
    proptest_lite::run(300, |rng| {
        let n = rng.gen_usize(1, 8);
        let mut macs: Vec<u64> = (0..n).map(|_| rng.gen_range(1_000_000) + 1).collect();
        let total = rng.gen_usize(n, 1024);
        let alloc = allocate_pes(&macs, total);
        prop_assert!(alloc.iter().sum::<usize>() == total);
        prop_assert!(alloc.iter().all(|&a| a >= 1));
        // a strictly dominant stage gets (within rounding) the largest
        // allocation
        let max_mac_idx = (0..n).max_by_key(|&i| macs[i]).unwrap();
        let max_alloc = *alloc.iter().max().unwrap();
        prop_assert!(
            alloc[max_mac_idx] + 1 >= max_alloc,
            "dominant stage under-allocated: {macs:?} -> {alloc:?}"
        );
        macs.sort_unstable();
        Ok(())
    });
}

#[test]
fn granularity_covers_tensor_for_random_nests() {
    use pipeorgan::dataflow::{DataflowStyle, LoopNest, Rank};
    use pipeorgan::ir::Op;
    use pipeorgan::pipeline::pair_granularity;
    proptest_lite::run(300, |rng| {
        let h = rng.gen_usize(2, 64);
        let c = rng.gen_usize(1, 64);
        let k = rng.gen_usize(1, 64);
        let op_p = Op::conv2d(1, h, h, c, k, 3, 3, 1, 1);
        let op_c = Op::conv2d(1, h, h, k, c, 3, 3, 1, 1);
        let styles = [
            DataflowStyle::ActivationStationary,
            DataflowStyle::MixedActivation,
            DataflowStyle::InputStationary,
            DataflowStyle::OutputStationary,
            DataflowStyle::WeightStationary,
        ];
        let mut np = LoopNest::for_op(&op_p, *rng.choose(&styles));
        let mut nc = LoopNest::for_op(&op_c, *rng.choose(&styles));
        if rng.gen_bool(0.5) {
            np.set_tile(Rank::H, rng.gen_range(8) + 1);
            nc.set_tile(Rank::H, rng.gen_range(8) + 1);
        }
        let total = op_p.output_act_words();
        let g = pair_granularity(&np, &nc, total);
        prop_assert!(g.words >= 1 && g.words <= total);
        prop_assert!(
            g.words * g.intervals >= total,
            "granularity {}x{} misses tensor {total}",
            g.words,
            g.intervals
        );
        prop_assert!(
            g.words.saturating_sub(1) * g.intervals < total,
            "granularity not tight: {}x{} vs {total}",
            g.words,
            g.intervals
        );
        Ok(())
    });
}

#[test]
fn channel_load_invariants_on_random_traffic() {
    use pipeorgan::noc::Topology;
    use pipeorgan::sim::analyze;
    use pipeorgan::traffic::{Flow, FlowClass};
    proptest_lite::run(100, |rng| {
        let kind = *rng.choose(&[
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ]);
        let rows = rng.gen_usize(2, 17);
        let cols = rng.gen_usize(2, 17);
        let topo = Topology::new(kind, rows, cols);
        let n_flows = rng.gen_usize(1, 64);
        let mut flows = Vec::new();
        let mut total_words = 0.0;
        for _ in 0..n_flows {
            let src = rng.gen_usize(0, rows * cols) as u32;
            let dst = rng.gen_usize(0, rows * cols) as u32;
            if src == dst {
                continue;
            }
            let words = (rng.gen_range(100) + 1) as f64;
            total_words += words;
            flows.push(Flow {
                src,
                dst,
                words_per_interval: words,
                class: FlowClass::Pipeline {
                    from_stage: 0,
                    to_stage: 1,
                },
            });
        }
        let a = analyze(&topo, &flows);
        // worst link carries at most all traffic, at least the mean
        prop_assert!(a.worst_channel_load <= total_words + 1e-6);
        let per_link_sum: f64 = a.per_link_words.iter().sum();
        prop_assert!(
            (per_link_sum - a.total_word_hops).abs() < 1e-6 * per_link_sum.max(1.0),
            "per-link sum {per_link_sum} != word-hops {}",
            a.total_word_hops
        );
        // wire length ≥ hops on mesh (unit links), ≥ hops on AMP too
        prop_assert!(a.total_word_wire + 1e-6 >= a.total_word_hops || flows.is_empty());
        Ok(())
    });
}

/// The NoC telemetry invariant on *random* traffic: a [`LinkLoadMap`]
/// built from the same analysis as the scalar cost metric has `max()`
/// equal to `worst_channel_load / interval` bit-exactly (division by a
/// positive constant is monotone, so max commutes with the scaling), the
/// summed per-link load conserves total word-hops, the wire-weighted sum
/// agrees with the routed wire length, and the verifier's distribution
/// stats are ordered. All four topology kinds, random shapes/intervals.
#[test]
fn link_loadmap_max_matches_scalar_bit_exactly_on_random_traffic() {
    use pipeorgan::noc::{percentile_of, verify_loads, LinkLoadMap, Topology};
    use pipeorgan::sim::analyze;
    use pipeorgan::traffic::{Flow, FlowClass};
    proptest_lite::run(100, |rng| {
        let kind = *rng.choose(&[
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ]);
        let rows = rng.gen_usize(2, 17);
        let cols = rng.gen_usize(2, 17);
        let topo = Topology::cached(kind, rows, cols);
        let mut flows = Vec::new();
        for _ in 0..rng.gen_usize(1, 64) {
            let src = rng.gen_usize(0, rows * cols) as u32;
            let dst = rng.gen_usize(0, rows * cols) as u32;
            if src == dst {
                continue;
            }
            flows.push(Flow {
                src,
                dst,
                words_per_interval: (rng.gen_range(100) + 1) as f64,
                class: FlowClass::Pipeline {
                    from_stage: 0,
                    to_stage: 1,
                },
            });
        }
        let a = analyze(&topo, &flows);
        let interval = (rng.gen_range(1000) + 1) as f64;
        let map = LinkLoadMap::from_analysis(topo.clone(), &a, interval);

        // The headline invariant, as an exact `==`, not a tolerance.
        prop_assert!(
            map.max() == a.worst_channel_load / interval,
            "{kind:?} {rows}x{cols}: map max {} != scalar {}",
            map.max(),
            a.worst_channel_load / interval
        );
        // Conservation: summed per-link load is all flit-hops (and the
        // wire-weighted sum is the routed wire length), up to the float
        // association of re-summing scaled terms.
        let hops = map.sum() * interval;
        prop_assert!(
            (hops - a.total_word_hops).abs() <= 1e-9 * a.total_word_hops.max(1.0),
            "{kind:?}: conservation {hops} vs {}",
            a.total_word_hops
        );
        let wire = map.wire_weighted_sum() * interval;
        prop_assert!(
            (wire - a.total_word_wire).abs() <= 1e-9 * a.total_word_wire.max(1.0),
            "{kind:?}: wire {wire} vs {}",
            a.total_word_wire
        );
        // Class totals partition every link exactly once.
        let class_sum: f64 = map.class_totals().iter().map(|(_, w)| w).sum();
        prop_assert!(
            (class_sum - map.sum()).abs() <= 1e-9 * map.sum().max(1.0),
            "{kind:?}: class partition {class_sum} vs {}",
            map.sum()
        );
        // The verifier's distribution is ordered, and saturation flips
        // exactly as the threshold crosses the max (strict comparison).
        let v = verify_loads(map.loads(), map.max());
        prop_assert!(v.p50 <= v.p95 && v.p95 <= v.max, "{kind:?}: unordered stats");
        prop_assert!(v.saturated == 0 && v.congestion_free);
        prop_assert!(percentile_of(map.loads(), 100.0) == map.max());
        if map.max() > 0.0 {
            let tight = verify_loads(map.loads(), map.max() * 0.5);
            prop_assert!(tight.saturated >= 1 && !tight.congestion_free);
        }
        // Element-wise max-merge of the map with itself is a fixpoint.
        let mut merged = map.clone();
        merged.merge_max(&map).map_err(|e| e.to_string())?;
        prop_assert!(merged.max() == map.max() && merged.sum() == map.sum());
        Ok(())
    });
}

/// Build a random feasible guillotine tree assigning tasks
/// `task0..task0 + count` to a `rows × cols` rectangle: random axis/cut/
/// split first, exhaustive fallback second (one always exists whenever
/// `rows * cols >= count`, so the builder never fails on feasible input).
fn random_cut_tree(
    rng: &mut SplitMix64,
    task0: usize,
    count: usize,
    rows: usize,
    cols: usize,
) -> CutTree {
    assert!(rows * cols >= count && count >= 1);
    let topology = *rng.choose(&[TopologyKind::Mesh, TopologyKind::Amp]);
    if count == 1 {
        return CutTree::Leaf {
            task: task0,
            topology,
        };
    }
    let build = |rng: &mut SplitMix64, vertical: bool, at: usize, k1: usize| -> CutTree {
        let (r1, c1, r2, c2) = if vertical {
            (rows, at, rows, cols - at)
        } else {
            (at, cols, rows - at, cols)
        };
        CutTree::Cut {
            axis: if vertical {
                CutAxis::Vertical
            } else {
                CutAxis::Horizontal
            },
            at,
            low: Box::new(random_cut_tree(rng, task0, k1, r1, c1)),
            high: Box::new(random_cut_tree(rng, task0 + k1, count - k1, r2, c2)),
        }
    };
    let feasible = |vertical: bool, at: usize, k1: usize| -> bool {
        let (a1, a2) = if vertical {
            (rows * at, rows * (cols - at))
        } else {
            (at * cols, (rows - at) * cols)
        };
        a1 >= k1 && a2 >= count - k1
    };
    for _ in 0..8 {
        let vertical = rng.gen_bool(0.5);
        let dim = if vertical { cols } else { rows };
        if dim < 2 {
            continue;
        }
        let at = rng.gen_usize(1, dim);
        let k1 = rng.gen_usize(1, count);
        if feasible(vertical, at, k1) {
            return build(rng, vertical, at, k1);
        }
    }
    for vertical in [true, false] {
        let dim = if vertical { cols } else { rows };
        for at in 1..dim {
            for k1 in 1..count {
                if feasible(vertical, at, k1) {
                    return build(rng, vertical, at, k1);
                }
            }
        }
    }
    unreachable!("a feasible guillotine cut always exists when area >= count >= 2")
}

#[test]
fn random_cut_trees_tile_the_array_exactly_and_round_trip() {
    proptest_lite::run(200, |rng| {
        let rows = rng.gen_usize(1, 33);
        let cols = rng.gen_usize(1, 33);
        let max_tasks = (rows * cols).min(6);
        let count = rng.gen_usize(1, max_tasks + 1);
        let tree = random_cut_tree(rng, 0, count, rows, cols);
        prop_assert!(
            tree.num_leaves() == count,
            "tree has {} leaves, wanted {count}",
            tree.num_leaves()
        );
        let (partition, topos) = tree
            .partition(rows, cols)
            .map_err(|e| format!("{rows}x{cols}/{count}: {e}"))?;
        if let Err(e) = partition.validate() {
            return Err(format!("{rows}x{cols}/{count}: {e}"));
        }
        // No overlap (validate), no gap, and PE counts sum to the array.
        let total: usize = partition.regions.iter().map(|r| r.num_pes()).sum();
        prop_assert!(
            total == rows * cols && partition.idle_pes() == 0,
            "{rows}x{cols}/{count}: covered {total}, idle {}",
            partition.idle_pes()
        );
        prop_assert!(
            partition.regions.len() == count && topos.len() == count,
            "one region and topology per task"
        );
        // Serialized plans round-trip through the report JSON path.
        let json_text = tree.to_json().to_pretty();
        let parsed = pipeorgan::util::json::Json::parse(&json_text)
            .map_err(|e| format!("reparse: {e}"))?;
        let back = CutTree::from_json(&parsed).map_err(|e| format!("from_json: {e}"))?;
        prop_assert!(back == tree, "cut tree JSON round-trip diverged");
        Ok(())
    });
}

#[test]
fn depth_cap_caps_and_flexible_dominates() {
    proptest_lite::run(40, |rng| {
        let g = random_model(rng, 14);
        let cfg = ArchConfig::default();
        let cap = rng.gen_usize(1, 6);
        let capped = PipeOrgan::with_depth_cap(cap).plan(&g, &cfg);
        prop_assert!(
            capped.segments.iter().all(|s| s.depth() <= cap),
            "segment exceeds cap {cap}"
        );
        Ok(())
    });
}

/// The guillotine DP's `u64`-bitset memo keys must agree with the sorted
/// `Vec<usize>` keys they replaced: same membership ⇒ same key, distinct
/// membership ⇒ distinct key, order/duplicate-insensitive construction,
/// and proper-subset enumeration identical to the classic
/// `lo = (lo - 1) & mask` walk over the sorted-Vec universe.
#[test]
fn bitset_task_keys_agree_with_sorted_vec_keys() {
    use pipeorgan::cosched::TaskSet;
    proptest_lite::run(200, |rng| {
        let universe = rng.gen_usize(1, 16);
        let mut tasks: Vec<usize> = (0..universe)
            .filter(|_| rng.gen_bool(0.5))
            .collect();
        let mut sorted = tasks.clone();
        sorted.sort_unstable();
        sorted.dedup();

        // Construction is order- and duplicate-insensitive.
        rng.shuffle(&mut tasks);
        if !tasks.is_empty() {
            let dup = tasks[rng.gen_usize(0, tasks.len())];
            tasks.push(dup);
        }
        let set = TaskSet::from_tasks(&tasks);
        prop_assert!(
            set == TaskSet::from_tasks(&sorted),
            "shuffled/duplicated construction diverged for {sorted:?}"
        );
        prop_assert!(
            set.to_sorted_vec() == sorted,
            "round-trip diverged: {:?} vs {sorted:?}",
            set.to_sorted_vec()
        );
        prop_assert!(set.len() == sorted.len(), "cardinality diverged");
        for t in 0..universe {
            prop_assert!(
                set.contains(t) == sorted.contains(&t),
                "membership of {t} diverged"
            );
        }

        // Distinct sorted-Vec keys map to distinct bitset keys.
        let mut other: Vec<usize> = (0..universe)
            .filter(|_| rng.gen_bool(0.5))
            .collect();
        other.sort_unstable();
        other.dedup();
        prop_assert!(
            (TaskSet::from_tasks(&other) == set) == (other == sorted),
            "key equality diverged for {other:?} vs {sorted:?}"
        );

        // Proper subsets: exactly the classic mask walk, which visits
        // every non-empty proper subset of the sorted-Vec universe.
        let mask = set.bits();
        let mut expected: Vec<u64> = Vec::new();
        let mut lo = mask.wrapping_sub(1) & mask;
        while lo != 0 {
            expected.push(lo);
            lo = lo.wrapping_sub(1) & mask;
        }
        let got: Vec<u64> = set.proper_subsets().map(TaskSet::bits).collect();
        prop_assert!(got == expected, "subset walk diverged for {sorted:?}");
        if !sorted.is_empty() {
            prop_assert!(
                got.len() == (1usize << sorted.len()) - 2,
                "subset count diverged for {sorted:?}"
            );
        }
        Ok(())
    });
}
