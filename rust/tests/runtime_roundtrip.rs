//! Integration tests over the PJRT runtime + functional pipelined executor.
//! These need `artifacts/` (built by `make artifacts`); they are skipped
//! with a notice when the artifacts are absent so `cargo test` stays green
//! on a fresh checkout.

use pipeorgan::coordinator as coord;
use pipeorgan::runtime::Runtime;

fn artifacts() -> Option<&'static str> {
    const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(DIR).join("manifest.json").exists() {
        Some(DIR)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_describes_all_programs() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(dir).unwrap();
    let m = rt.manifest().unwrap();
    for name in [
        "segment_fused",
        "layer0",
        "layer1",
        "tile_layer0",
        "tile_layer1",
        "gemm",
    ] {
        assert!(m.program(name).is_some(), "missing {name}");
    }
    assert_eq!(m.segment.h % m.segment.band, 0);
}

#[test]
fn gemm_artifact_matches_host_matmul() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(dir).unwrap();
    let prog = rt.load_program("gemm").unwrap();
    let n = 64usize;
    let a: Vec<f32> = (0..n * n).map(|i| ((i * 13 + 7) % 11) as f32 * 0.1).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i * 5 + 3) % 9) as f32 * 0.1).collect();
    let got = prog.run_f32(&[&a, &b]).unwrap();
    for &(r, c) in &[(0usize, 0usize), (5, 9), (31, 63), (63, 1)] {
        let want: f32 = (0..n).map(|k| a[r * n + k] * b[k * n + c]).sum();
        assert!(
            (got[r * n + c] - want).abs() < 1e-3,
            "({r},{c}): got {} want {want}",
            got[r * n + c]
        );
    }
}

#[test]
fn wrong_input_shape_is_rejected() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(dir).unwrap();
    let prog = rt.load_program("gemm").unwrap();
    let too_small = vec![0f32; 16];
    assert!(prog.run_f32(&[&too_small, &too_small]).is_err());
    let ok = vec![0f32; 64 * 64];
    assert!(prog.run_f32(&[&ok]).is_err(), "arity check");
}

#[test]
fn pipelined_equals_fused_equals_op_by_op() {
    // E15 acceptance: the three execution modes agree numerically.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(dir).unwrap();
    let data = coord::SegmentData::random(rt.manifest().unwrap().segment, 7);
    let op = coord::run_op_by_op(dir, &data).unwrap();
    let fused = coord::run_fused(dir, &data).unwrap();
    let piped = coord::run_pipelined(dir, &data).unwrap();
    assert!(coord::compare_outputs(&op, &fused).unwrap() < 1e-3);
    assert!(coord::compare_outputs(&op, &piped).unwrap() < 1e-3);
    assert_eq!(piped.tiles, data.spec.h / data.spec.band);
}

#[test]
fn pipelined_is_deterministic_across_runs() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(dir).unwrap();
    let data = coord::SegmentData::random(rt.manifest().unwrap().segment, 99);
    let a = coord::run_pipelined(dir, &data).unwrap();
    let b = coord::run_pipelined(dir, &data).unwrap();
    assert_eq!(a.output, b.output);
}
