//! Integration tests for the serve subsystem: EDF never misses more than
//! FIFO on any canned scenario at a shared seed/rate, the rate sweep's
//! schedulability boundary is monotone, same-seed runs are bit-identical
//! (property-tested) while Poisson arrivals differ across seeds, the
//! dynamic bandwidth model never serves slower than the static split, and
//! the CLI flag surface stays strict.

use pipeorgan::cli::Args;
use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{
    canned_scenarios, scenario_by_name, CoschedConfig, PartitionKind, Scenario,
};
use pipeorgan::dse::EvalCache;
use pipeorgan::prop_assert;
use pipeorgan::serve::{
    plan_scenario, run_scenario, simulate, streams, sweep_max_rate, ArrivalProcess,
    BandwidthModel, Policy, ServeConfig, ServePlan, SimOptions, SERVE_FLAGS,
};
use pipeorgan::util::proptest_lite;

/// A smaller array than Table III keeps debug-build evaluation fast; every
/// asserted property is architecture-independent.
fn small_cfg() -> ArchConfig {
    ArchConfig {
        pe_rows: 16,
        pe_cols: 16,
        ..ArchConfig::default()
    }
}

fn periodic_arrivals(sc: &Scenario, mult: f64, duration_s: f64) -> Vec<Vec<f64>> {
    streams(sc, &ArrivalProcess::Periodic, mult, duration_s, 0)
}

/// The acceptance criterion: on every canned scenario, at the same
/// arrival replay, EDF's deadline-miss rate never exceeds FIFO's — in the
/// feasible regime both are zero, and under overload EDF's hopeless-drop
/// rule spends capacity only on requests that can still make it while
/// FIFO burns it on doomed ones.
#[test]
fn edf_never_misses_more_than_fifo_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        for mult in [1.0, 8.0] {
            let arrivals = periodic_arrivals(&sc, mult, 0.05);
            let fifo = simulate(&sc, &plan, Policy::Fifo, &arrivals, SimOptions::default());
            let edf = simulate(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default());
            assert!(
                edf.miss_rate() <= fifo.miss_rate() + 1e-12,
                "{} @ {mult}x: EDF miss rate {} > FIFO {}",
                sc.name,
                edf.miss_rate(),
                fifo.miss_rate()
            );
            // Per-task accounting always closes.
            for out in [&fifo, &edf] {
                for (t, m) in out.tasks.iter().enumerate() {
                    assert_eq!(
                        m.completed + m.dropped,
                        arrivals[t].len() as u64,
                        "{} {} {}",
                        sc.name,
                        out.policy.name(),
                        m.task
                    );
                    assert!(m.missed <= m.requests);
                }
            }
        }
    }
}

/// Rate-monotonic is deadline-aware like EDF, so the same dominance holds
/// against the blind FIFO baseline on the canned scenarios.
#[test]
fn rm_never_misses_more_than_fifo_on_xr_core() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    for mult in [1.0, 8.0] {
        let arrivals = periodic_arrivals(&sc, mult, 0.05);
        let fifo = simulate(&sc, &plan, Policy::Fifo, &arrivals, SimOptions::default());
        let rm = simulate(&sc, &plan, Policy::Rm, &arrivals, SimOptions::default());
        assert!(
            rm.miss_rate() <= fifo.miss_rate() + 1e-12,
            "@ {mult}x: RM {} > FIFO {}",
            rm.miss_rate(),
            fifo.miss_rate()
        );
    }
}

/// The sweep's probe record must be consistent with a monotone
/// schedulability boundary: no multiplier may be infeasible while a
/// *larger* one is feasible.
#[test]
fn sweep_boundary_is_monotone_on_every_canned_scenario() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
        for policy in [Policy::Fifo, Policy::Edf] {
            let sweep = sweep_max_rate(&sc, &plan, policy, SimOptions::default(), 0.05);
            assert!(!sweep.probes.is_empty());
            assert!(sweep.max_mult >= 0.0);
            for &(m_lo, ok_lo) in &sweep.probes {
                for &(m_hi, ok_hi) in &sweep.probes {
                    assert!(
                        !(m_lo < m_hi && !ok_lo && ok_hi),
                        "{} {}: non-monotone probes ({m_lo}, {ok_lo}) vs ({m_hi}, {ok_hi})",
                        sc.name,
                        policy.name()
                    );
                }
            }
            // The reported boundary is itself a feasible probe (or 0).
            if sweep.max_mult > 0.0 {
                assert!(
                    sweep.probes.iter().any(|&(m, ok)| m == sweep.max_mult && ok),
                    "{} {}: boundary {} was never probed feasible",
                    sc.name,
                    policy.name(),
                    sweep.max_mult
                );
            }
        }
    }
}

/// Same seed → bit-identical event traces and metrics, for every policy;
/// property-tested over random seeds. Poisson arrival streams must differ
/// across seeds (that is what the seed is for).
#[test]
fn serving_is_deterministic_per_seed_property() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    proptest_lite::run(16, |rng| {
        let seed = rng.next_u64();
        let policy = *rng.choose(&Policy::ALL);
        let borrow = rng.gen_bool(0.5);
        let make_arrivals = |seed: u64| -> Vec<Vec<f64>> {
            streams(&sc, &ArrivalProcess::Poisson, 1.0, 0.05, seed)
        };
        let arrivals = make_arrivals(seed);
        let opts = SimOptions {
            borrow,
            ..SimOptions::default()
        };
        let a = simulate(&sc, &plan, policy, &arrivals, opts);
        let b = simulate(&sc, &plan, policy, &make_arrivals(seed), opts);
        prop_assert!(a.trace == b.trace, "trace diverged at seed {seed:#x}");
        prop_assert!(a.tasks == b.tasks, "metrics diverged at seed {seed:#x}");
        prop_assert!(a.span_s == b.span_s, "span diverged at seed {seed:#x}");
        // A different seed must produce a different Poisson stream.
        let other = make_arrivals(seed ^ 0x9E37_79B9_7F4A_7C15);
        prop_assert!(
            arrivals != other,
            "distinct seeds produced identical Poisson arrivals (seed {seed:#x})"
        );
        Ok(())
    });
}

/// The dynamic contention model may only ever *donate* bandwidth, so under
/// FIFO (same service order, no drops) every task's tail latencies and
/// miss counts are no worse than under the static split.
#[test]
fn dynamic_bandwidth_never_worse_than_static_on_canned_scenarios() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    for sc in canned_scenarios() {
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
        let arrivals = periodic_arrivals(&sc, 2.0, 0.05);
        let run = |bandwidth| {
            simulate(
                &sc,
                &plan,
                Policy::Fifo,
                &arrivals,
                SimOptions {
                    bandwidth,
                    ..SimOptions::default()
                },
            )
        };
        let stat = run(BandwidthModel::Static);
        let dynamic = run(BandwidthModel::Dynamic);
        for (s, d) in stat.tasks.iter().zip(&dynamic.tasks) {
            assert_eq!(s.completed, d.completed, "{}: {}", sc.name, s.task);
            assert!(
                d.missed <= s.missed,
                "{} {}: dynamic missed {} > static {}",
                sc.name,
                s.task,
                d.missed,
                s.missed
            );
            for (pd, ps) in [(d.p50_ms, s.p50_ms), (d.p95_ms, s.p95_ms), (d.p99_ms, s.p99_ms)] {
                assert!(
                    pd <= ps + 1e-6,
                    "{} {}: dynamic {pd} > static {ps}",
                    sc.name,
                    s.task
                );
            }
        }
        assert!(dynamic.span_s <= stat.span_s + 1e-9);
    }
}

/// Serving costs must agree with the co-scheduler's cost model on each
/// task's home region: same shared cache entries, same latency.
#[test]
fn home_region_costs_match_cosched() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-hands").unwrap();
    let plan: ServePlan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    for (t, a) in plan.cosched.cosched.assignments.iter().enumerate() {
        let own = &plan.costs[t][t];
        assert!(
            (own.nominal_cycles - a.latency_cycles).abs() <= 1e-6 * a.latency_cycles.max(1.0),
            "task {t}: serve nominal {} vs cosched {}",
            own.nominal_cycles,
            a.latency_cycles
        );
        assert!(own.best_case_cycles <= own.nominal_cycles * (1.0 + 1e-9));
    }
    // Replanning against the same cache is fully memoized.
    let again = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
    assert_eq!(again.evaluations, 0, "warm replan must be all cache hits");
    assert!(again.cache_hits > 0);
}

/// End-to-end CLI-level run: all policies on one scenario share arrivals,
/// and the run is reproducible from its seed.
#[test]
fn run_scenario_end_to_end_is_deterministic() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let sv = ServeConfig {
        duration_s: 0.05,
        arrivals: ArrivalProcess::Poisson,
        seed: 7,
        ..ServeConfig::default()
    };
    let a = run_scenario(&sc, &cfg, &sv, &cache, 2).unwrap();
    let b = run_scenario(&sc, &cfg, &sv, &cache, 2).unwrap();
    assert_eq!(a.outcomes.len(), 3);
    for (oa, ob) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(oa.policy, ob.policy);
        assert_eq!(oa.trace, ob.trace);
        assert_eq!(oa.tasks, ob.tasks);
    }
    // All policies replay identical arrival streams: per-task request
    // counts agree across policies.
    for o in &a.outcomes {
        for (t, m) in o.tasks.iter().enumerate() {
            assert_eq!(m.requests, a.outcomes[0].tasks[t].requests);
        }
    }
}

/// The acceptance criterion's serve half: `pipeorgan serve` runs end to
/// end on a guillotine plan — planning, simulation and accounting all
/// hold on arbitrary-rectangle partitions, and the guillotine plan's
/// makespan never loses to the band plan it was seeded with.
#[test]
fn serve_runs_end_to_end_on_a_guillotine_plan() {
    let cfg = small_cfg();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").unwrap();
    let sv = ServeConfig {
        partition: PartitionKind::Guillotine,
        duration_s: 0.05,
        ..ServeConfig::default()
    };
    let run = run_scenario(&sc, &cfg, &sv, &cache, 2).unwrap();
    assert_eq!(run.plan.cosched.partition, PartitionKind::Guillotine);
    assert_eq!(run.plan.regions.len(), sc.tasks.len());
    assert_eq!(run.plan.topologies.len(), sc.tasks.len());
    // The served regions are exactly the cut tree's realization.
    let (partition, topos) = run
        .plan
        .cosched
        .cut_tree
        .partition(cfg.pe_rows, cfg.pe_cols)
        .unwrap();
    assert_eq!(partition.regions, run.plan.regions);
    assert_eq!(topos, run.plan.topologies);
    for o in &run.outcomes {
        for m in &o.tasks {
            assert_eq!(m.completed + m.dropped, m.requests, "{}", m.task);
        }
    }
    // Never-lose carries through to the served plan's makespan.
    let bands = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 2).unwrap();
    assert!(
        run.plan.cosched.cosched.makespan_cycles
            <= bands.cosched.cosched.makespan_cycles * 1.0001,
        "guillotine {} vs bands {}",
        run.plan.cosched.cosched.makespan_cycles,
        bands.cosched.cosched.makespan_cycles
    );
}

#[test]
fn serve_cli_flags_are_strict() {
    let mut flags: Vec<(&str, bool)> = vec![("out", true), ("workers", true), ("seed", true)];
    flags.extend_from_slice(SERVE_FLAGS);
    let parse = |v: &[&str]| {
        let raw: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        Args::parse(&raw, &flags)
    };
    let args = parse(&[
        "serve",
        "--scenario",
        "xr-core",
        "--policy",
        "edf",
        "--seed",
        "7",
        "--duration-s",
        "0.25",
        "--rate-mult",
        "1.5",
        "--sweep",
        "--cache-file",
        "reports/dse_cache.json",
    ])
    .unwrap();
    let sv = ServeConfig::from_cli(&args, 7).unwrap();
    assert_eq!(sv.policies, vec![Policy::Edf]);
    assert_eq!(sv.duration_s, 0.25);
    assert_eq!(sv.rate_mult, 1.5);
    assert!(sv.sweep);
    // Typos and foreign subcommand flags stay hard errors on serve.
    assert!(parse(&["serve", "--policey", "edf"]).is_err());
    assert!(parse(&["serve", "--quantum", "4"]).is_err());
    assert!(parse(&["serve", "--beam", "4"]).is_err());
    // --partition parses on serve exactly as on cosched.
    let args = parse(&["serve", "--partition", "guillotine"]).unwrap();
    assert_eq!(
        ServeConfig::from_cli(&args, 7).unwrap().partition,
        PartitionKind::Guillotine
    );
    let args = parse(&["serve", "--partition", "diagonal"]).unwrap();
    assert!(ServeConfig::from_cli(&args, 7).is_err());
}
