//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This environment has no network access, so the real crate cannot be
//! fetched from crates.io; this vendored implementation provides the small
//! API surface the workspace actually uses:
//!
//! - [`Error`] / [`Result`] with `?`-conversion from any
//!   `std::error::Error + Send + Sync + 'static`,
//! - the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! - the [`Context`] extension trait on `Result` and `Option`,
//! - chain-aware alternate formatting: `{e:#}` prints `outer: inner: root`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error with an optional chain of sources.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// Create an error from an existing `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            inner: Box::new(error),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            inner: Box::new(ContextError {
                msg: context.to_string(),
                source: self.inner,
            }),
        }
    }

    /// Iterate the chain from the outermost error to the root cause.
    pub fn chain(&self) -> Chain<'_> {
        Chain {
            next: Some(&*self.inner),
        }
    }

    /// The innermost (root) cause.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

/// Iterator over an error chain (see [`Error::chain`]).
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let current = self.next?;
        self.next = current.source();
        Some(current)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            for cause in self.chain().skip(1) {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Message-only error (no source).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context message layered over an underlying error.
struct ContextError {
    msg: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.msg, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(&*self.source)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn alternate_display_prints_chain() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err())
            .context("reading manifest.json");
        let e = e.unwrap_err();
        let s = format!("{e:#}");
        assert!(s.contains("reading manifest.json"), "{s}");
        assert!(s.contains("missing thing"), "{s}");
        // non-alternate shows only the outermost message
        assert_eq!(format!("{e}"), "reading manifest.json");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("value absent").unwrap_err();
        assert_eq!(format!("{e}"), "value absent");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{:#}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{:#}", f(5).unwrap_err()).contains("five"));
        let msg = "boom".to_string();
        let e = anyhow!(msg);
        assert_eq!(format!("{e}"), "boom");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::new(io_err()).context("layer 1").context("layer 2");
        assert_eq!(e.chain().count(), 3);
        assert_eq!(format!("{}", e.root_cause()), "missing thing");
    }
}
