//! Minimal offline stand-in for the `log` facade.
//!
//! No pluggable logger registry — records above the compile-time threshold
//! go straight to stderr with a level prefix, which is all the workspace
//! needs (background worker threads reporting failures).

use std::fmt;

/// Log verbosity levels, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Everything at or above this level is printed.
pub const MAX_LEVEL: Level = Level::Info;

/// Emit one record (used by the level macros; not called directly).
pub fn __emit(level: Level, args: fmt::Arguments<'_>) {
    if level <= MAX_LEVEL {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Trace);
    }

    #[test]
    fn macros_expand() {
        // Just exercise the expansion paths; output goes to stderr.
        error!("e {}", 1);
        warn!("w");
        info!("i {x}", x = 2);
        debug!("suppressed");
        trace!("suppressed");
    }
}
