//! Minimal offline stand-in for the `once_cell` crate, backed by
//! `std::sync::OnceLock`. Only the `sync::Lazy` surface the workspace uses
//! is provided.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access. Usable in `static` items:
    /// the default initializer type is a plain fn pointer, so capture-free
    /// closures coerce to it.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy {
                cell: OnceLock::new(),
                init,
            }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        /// Force initialization and return the value.
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(&this.init)
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::sync::atomic::{AtomicUsize, Ordering};

        static CALLS: AtomicUsize = AtomicUsize::new(0);
        static VALUE: Lazy<usize> = Lazy::new(|| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            41 + 1
        });

        #[test]
        fn initializes_once_in_static() {
            assert_eq!(*VALUE, 42);
            assert_eq!(*VALUE, 42);
            assert_eq!(CALLS.load(Ordering::SeqCst), 1);
        }

        #[test]
        fn works_with_local_closures() {
            let lazy = Lazy::new(|| vec![1, 2, 3]);
            assert_eq!(lazy.len(), 3);
        }
    }
}
