//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The real crate links the native XLA/PJRT library, which cannot be
//! downloaded in this environment. This stub keeps the same API shape so
//! `pipeorgan::runtime` compiles and degrades gracefully:
//!
//! - client creation succeeds (so manifest handling, error paths and the
//!   failure-injection tests behave normally),
//! - anything that would actually load or execute an HLO program returns a
//!   typed [`Error`] explaining that the native backend is unavailable.
//!
//! Swapping the real bindings back in is a one-line change in Cargo.toml;
//! no source changes are needed.

use std::fmt;

/// Error type mirroring `xla::Error` far enough for `?`-conversion into
/// `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "XLA/PJRT native backend unavailable in this offline build ({what}); \
         link the real `xla` crate to execute AOT artifacts"
    ))
}

/// Stub PJRT client. Creation succeeds; compilation reports the missing
/// backend.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu(no-xla)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub HLO module proto handle.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({path})"
        )))
    }
}

/// Stub computation handle.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Element types `Literal::to_vec` can produce.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}

/// Stub host literal.
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_succeeds_but_compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let proto_err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(proto_err.to_string().contains("unavailable"), "{proto_err}");
    }

    #[test]
    fn literal_shape_ops_are_inert() {
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
