#!/usr/bin/env python3
"""Aggregate raw bench records and gate CI on perf regressions.

Usage: python3 tools/bench_check.py [raw_jsonl] [baseline_json] [out_json]
       python3 tools/bench_check.py --promote [--dry-run] [--markdown] \
           [ci_json] [baseline_json]
       python3 tools/bench_check.py --compare A.json B.json [--markdown]

Reads the JSONL file the bench harness appends to when PIPEORGAN_BENCH_JSON
is set (one record per bench run: {"bench": name, "mean_ns": ..., "p50_ns":
..., ...}; the last record per name wins), writes the aggregated
BENCH_ci.json artifact, then compares against the checked-in baseline:

  - a bench whose p50_ns exceeds baseline p50_ns * BENCH_MAX_RATIO
    (env var, default 2.0) fails the gate; a baseline entry may override
    the global ratio with its own "max_ratio" field (how the tentpole
    benches pin their locked-in speedups, see docs/PERFORMANCE.md);
  - a baseline bench missing from the run fails the gate (renamed or
    deleted hot paths must update BENCH_baseline.json deliberately);
  - benches not in the baseline are reported as new, never fatal;
  - a baseline entry with p50_ns null is a record-only placeholder —
    promote a green CI run's BENCH_ci.json numbers to arm it.

Exit status 0 iff the gate passes. The artifact is written in all cases so
the bench trajectory accumulates even across red runs.

`--promote` arms or tightens the gate from a green run: every bench already
in the baseline takes its p50_ns from the given BENCH_ci.json (default
reports/BENCH_ci.json). Names in the CI artifact but not in the baseline —
e.g. the obs layer's `time.*` self-profiling records, which only exist on
`--obs` runs — are listed but never added, because a baseline entry makes
the bench mandatory on every future run. `--dry-run` prints the promote
diff without rewriting the baseline — the bench-smoke CI job runs it on
every green build so the step summary always shows what a promote would
change (the runbook in docs/PERFORMANCE.md); `--markdown` renders that
diff as a GitHub table.

`--compare` prints a per-bench speedup table between two bench artifacts
(BENCH_ci.json or BENCH_baseline.json — anything with a `benches` map of
`p50_ns` entries). Speedup is A/B, so `--compare before.json after.json`
reads as "after is N.NNx faster". `--markdown` emits a GitHub table (the
bench-smoke job appends it to the step summary; paste it into PR
descriptions). Never fails: comparison is reporting, not gating.
"""

import json
import os
import sys


def read_records(path):
    benches = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            benches[rec["bench"]] = rec
    return benches


def promote(argv):
    dry_run = "--dry-run" in argv
    markdown = "--markdown" in argv
    paths = [a for a in argv if not a.startswith("--")]
    ci_path = paths[0] if len(paths) > 0 else "reports/BENCH_ci.json"
    baseline_path = paths[1] if len(paths) > 1 else "BENCH_baseline.json"
    with open(ci_path) as f:
        benches = json.load(f).get("benches", {})
    if not benches:
        print(f"error: no benches in {ci_path}", file=sys.stderr)
        return 1
    with open(baseline_path) as f:
        doc = json.load(f)
    entries = doc.get("benches", {})

    updated, skipped = [], []
    for name in sorted(benches):
        p50 = benches[name].get("p50_ns")
        if p50 is None:
            continue
        if name in entries:
            old = entries[name].get("p50_ns")
            entries[name]["p50_ns"] = p50
            updated.append((name, old, p50))
        else:
            skipped.append(name)

    if not dry_run:
        with open(baseline_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")

    fmt = lambda ns: f"{ns / 1e6:.3f} ms" if ns is not None else "null"
    if markdown:
        verb = "would promote" if dry_run else "promoted"
        print(f"| bench | baseline p50 | {verb} to | delta |")
        print("|---|---:|---:|---:|")
        for name, old, new in updated:
            delta = f"{new / old:.2f}x" if old else "arm"
            print(f"| {name} | {fmt(old)} | {fmt(new)} | {delta} |")
        for name in skipped:
            print(f"| {name} | (not in baseline) | - | skip |")
    else:
        verb = "would promote" if dry_run else "promote"
        for name, old, new in updated:
            print(f"{verb} {name}: {fmt(old)} -> {fmt(new)}")
        if skipped:
            print(f"skipped (not in baseline, add by hand to gate): {', '.join(skipped)}")
    if dry_run:
        print(f"dry run: {len(updated)} baselines would change; {baseline_path} untouched")
    else:
        print(f"promoted {len(updated)} baselines from {ci_path} -> {baseline_path}")
    return 0


def load_benches(path):
    with open(path) as f:
        return json.load(f).get("benches", {})


def compare(argv):
    markdown = "--markdown" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 2:
        print("usage: bench_check.py --compare A.json B.json [--markdown]", file=sys.stderr)
        return 1
    a_path, b_path = paths
    a, b = load_benches(a_path), load_benches(b_path)

    rows = []
    for name in sorted(set(a) | set(b)):
        a_p50 = a.get(name, {}).get("p50_ns")
        b_p50 = b.get(name, {}).get("p50_ns")
        speedup = None
        if a_p50 is not None and b_p50 is not None and b_p50 > 0:
            speedup = float(a_p50) / float(b_p50)
        rows.append((name, a_p50, b_p50, speedup))

    fmt = lambda ns: f"{ns / 1e6:.3f} ms" if ns is not None else "-"
    spd = lambda s: f"{s:.2f}x" if s is not None else "-"
    if markdown:
        print(f"| bench | {a_path} p50 | {b_path} p50 | speedup (A/B) |")
        print("|---|---:|---:|---:|")
        for name, a_p50, b_p50, speedup in rows:
            print(f"| {name} | {fmt(a_p50)} | {fmt(b_p50)} | {spd(speedup)} |")
    else:
        width = max(len(name) for name, *_ in rows) if rows else 5
        print(f"{'bench':<{width}}  {'A p50':>12}  {'B p50':>12}  {'A/B':>7}")
        for name, a_p50, b_p50, speedup in rows:
            print(f"{name:<{width}}  {fmt(a_p50):>12}  {fmt(b_p50):>12}  {spd(speedup):>7}")
    return 0


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "--promote":
        return promote(sys.argv[2:])
    if len(sys.argv) > 1 and sys.argv[1] == "--compare":
        return compare(sys.argv[2:])
    raw_path = sys.argv[1] if len(sys.argv) > 1 else "reports/bench_raw.jsonl"
    baseline_path = sys.argv[2] if len(sys.argv) > 2 else "BENCH_baseline.json"
    out_path = sys.argv[3] if len(sys.argv) > 3 else "reports/BENCH_ci.json"
    max_ratio = float(os.environ.get("BENCH_MAX_RATIO", "2.0"))

    benches = read_records(raw_path)
    if not benches:
        print(f"error: no bench records in {raw_path}", file=sys.stderr)
        return 1

    baseline = {}
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            baseline = json.load(f).get("benches", {})
    else:
        print(f"warning: no baseline at {baseline_path}; recording only")

    failures = []
    rows = []
    for name in sorted(set(baseline) | set(benches)):
        base = baseline.get(name)
        cur = benches.get(name)
        if cur is None:
            failures.append(
                f"{name}: in baseline but not produced by this run "
                f"(renamed/deleted hot paths must update {baseline_path})"
            )
            rows.append((name, base.get("p50_ns"), None, None, "MISSING"))
            continue
        if base is None:
            rows.append((name, None, cur["p50_ns"], None, "new"))
            continue
        base_p50 = base.get("p50_ns")
        if base_p50 is None:
            rows.append((name, None, cur["p50_ns"], None, "record-only"))
            continue
        limit = float(base.get("max_ratio", max_ratio))
        ratio = cur["p50_ns"] / max(float(base_p50), 1.0)
        verdict = "ok" if ratio <= limit else "REGRESSED"
        if ratio > limit:
            failures.append(
                f"{name}: p50 {cur['p50_ns'] / 1e6:.2f} ms is {ratio:.2f}x the "
                f"baseline {base_p50 / 1e6:.2f} ms (limit {limit:.1f}x)"
            )
        rows.append((name, base_p50, cur["p50_ns"], ratio, verdict))

    report = {
        "schema": 1,
        "metric": "p50_ns",
        "max_ratio": max_ratio,
        "benches": benches,
        "failures": failures,
    }
    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    width = max(len(name) for name, *_ in rows)
    print(f"{'bench':<{width}}  {'base p50':>12}  {'ci p50':>12}  {'ratio':>6}  verdict")
    for name, base_p50, cur_p50, ratio, verdict in rows:
        fmt = lambda ns: f"{ns / 1e6:.3f} ms" if ns is not None else "-"
        r = f"{ratio:.2f}x" if ratio is not None else "-"
        print(f"{name:<{width}}  {fmt(base_p50):>12}  {fmt(cur_p50):>12}  {r:>6}  {verdict}")
    print(f"\nwrote {out_path} ({len(benches)} benches)")

    if failures:
        print(f"\nbench gate FAILED ({len(failures)}):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
