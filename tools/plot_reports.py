#!/usr/bin/env python3
"""Plot the reproduced paper figures from reports/*.json.

Usage: python tools/plot_reports.py [reports_dir] [out_dir]

Produces PNG counterparts of the paper's evaluation figures:
  fig13_performance.png  — grouped bars, normalized performance per task
  fig14_dram.png         — grouped bars, normalized DRAM accesses
  fig15_congestion.png   — delay factor vs compute interval (log-x)
  fig16_depth.png        — depth profile per task
  fig5_aw_ratios.png     — per-task A/W ratio ranges (log-y)
  obs_timeline.png       — serve queue-depth / utilization timeline, from
                           a --trace-out export saved as reports/trace.json
  attr_breakdown.png     — stacked queue/compute/DRAM latency breakdown per
                           (scenario, policy, task), from the `attr` blocks
                           in reports/serve.json (see docs/OBSERVABILITY.md)
  noc_heatmap_*.png      — per-link congestion heatmaps (one panel per wire
                           direction, idle rectangles hatched) from any
                           `pipeorgan-noc-v1` document in the reports dir
                           (reports/noc_{dse,cosched,serve}.json or a
                           --noc-out file; see docs/OBSERVABILITY.md §NoC
                           telemetry)
"""

import json
import os
import sys

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt
import numpy as np


def load(reports, name):
    path = os.path.join(reports, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def short(task):
    return task.replace("_", "\n")


def plot_fig13(reports, out):
    data = load(reports, "fig13_performance")
    if not data:
        return
    rows = data["rows"]
    tasks = [r["task"] for r in rows]
    x = np.arange(len(tasks))
    w = 0.27
    fig, ax = plt.subplots(figsize=(11, 4))
    ax.bar(x - w, [r["pipeorgan"] for r in rows], w, label="PipeOrgan")
    ax.bar(x, [1.0] * len(rows), w, label="TANGRAM-like")
    ax.bar(x + w, [r["simba_like"] for r in rows], w, label="SIMBA-like")
    ax.axhline(1.0, color="gray", lw=0.5)
    ax.set_xticks(x)
    ax.set_xticklabels([short(t) for t in tasks], fontsize=7)
    ax.set_ylabel("normalized performance (higher = better)")
    ax.set_title(
        f"Fig. 13 — end-to-end performance "
        f"(geomean PipeOrgan {data['geomean_pipeorgan_vs_tangram']:.2f}x; paper 1.95x)"
    )
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig13_performance.png"), dpi=150)
    plt.close(fig)


def plot_fig14(reports, out):
    data = load(reports, "fig14_dram")
    if not data:
        return
    rows = data["rows"]
    tasks = [r["task"] for r in rows]
    x = np.arange(len(tasks))
    w = 0.27
    fig, ax = plt.subplots(figsize=(11, 4))
    ax.bar(x - w, [r["pipeorgan"] for r in rows], w, label="PipeOrgan")
    ax.bar(x, [1.0] * len(rows), w, label="TANGRAM-like")
    ax.bar(x + w, [r["simba_like"] for r in rows], w, label="SIMBA-like")
    ax.set_xticks(x)
    ax.set_xticklabels([short(t) for t in tasks], fontsize=7)
    ax.set_ylabel("normalized DRAM accesses (lower = better)")
    ax.set_title(
        f"Fig. 14 — DRAM accesses "
        f"(geomean reduction {100 * data['geomean_reduction']:.0f}%; paper 31%)"
    )
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig14_dram.png"), dpi=150)
    plt.close(fig)


def plot_fig15(reports, out):
    data = load(reports, "fig15_congestion")
    if not data:
        return
    rows = [r for r in data["rows"] if r["alloc"] == "equal"]
    xs = [r["compute_interval"] for r in rows]
    fig, ax = plt.subplots(figsize=(6, 4))
    for key, label in [
        ("blocked_mesh", "blocked / mesh"),
        ("fine1d_mesh", "fine-striped 1-D / mesh"),
        ("blocked_amp", "blocked / AMP"),
    ]:
        ax.plot(xs, [r[key] for r in rows], marker="o", label=label)
    ax.set_xscale("log", base=2)
    ax.set_xlabel("compute interval (cycles)")
    ax.set_ylabel("interval delay factor")
    ax.set_title("Fig. 15 — congestion vs compute interval (depth-2, 1-D, 32x32)")
    ax.legend()
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig15_congestion.png"), dpi=150)
    plt.close(fig)


def plot_fig16(reports, out):
    data = load(reports, "fig16_depth")
    if not data:
        return
    fig, ax = plt.subplots(figsize=(10, 4))
    for i, t in enumerate(data["tasks"]):
        depths = t["depths"]
        # expand segment depths to per-layer positions
        layers = []
        for d in depths:
            layers.extend([d] * int(d))
        ax.step(range(len(layers)), layers, where="post", label=t["task"], alpha=0.8)
    ax.set_xlabel("layer index")
    ax.set_ylabel("segment depth")
    ax.set_title("Fig. 16 — pipeline depths across tasks")
    ax.legend(fontsize=6, ncol=3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig16_depth.png"), dpi=150)
    plt.close(fig)


def plot_fig5(reports, out):
    data = load(reports, "fig5_aw_ratios")
    if not data:
        return
    fig, ax = plt.subplots(figsize=(10, 4))
    for i, t in enumerate(data["tasks"]):
        ratios = np.array(t["ratios"])
        ax.scatter([i] * len(ratios), ratios, s=8, alpha=0.5)
    ax.set_yscale("log")
    ax.axhline(1.0, color="gray", lw=0.5)
    ax.set_xticks(range(len(data["tasks"])))
    ax.set_xticklabels([short(t["task"]) for t in data["tasks"]], fontsize=7)
    ax.set_ylabel("activation / weight ratio (log)")
    ax.set_title("Fig. 5 — A/W ratios across XR-bench-like tasks")
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig5_aw_ratios.png"), dpi=150)
    plt.close(fig)


def plot_cosched(reports, out):
    """Per-scenario makespans for solo / even-split / co-scheduled.

    Newer reports carry 2-D partitioning fields (`partition`, `cut_tree`,
    `cut_tree_str`, per-task `region_row0`/`topology`); older ones do not —
    every access below degrades gracefully so both plot.
    """
    data = load(reports, "cosched")
    if not data:
        return
    scenarios = data.get("scenarios", [])
    if not scenarios:
        return
    names = [s.get("scenario", f"s{i}") for i, s in enumerate(scenarios)]
    modes = [("solo", "solo"), ("even_split", "even split"), ("cosched", "co-scheduled")]
    x = np.arange(len(scenarios))
    w = 0.27
    fig, ax = plt.subplots(figsize=(max(6, 2.5 * len(scenarios)), 4))
    for k, (key, label) in enumerate(modes):
        ys = [s.get(key, {}).get("makespan_cycles", 0.0) for s in scenarios]
        ax.bar(x + (k - 1) * w, ys, w, label=label)
    for i, s in enumerate(scenarios):
        # Annotate the winning partition when the report is new enough to
        # carry it (partition kind + compact cut-tree encoding).
        parts = [p for p in (s.get("partition"), s.get("cut_tree_str")) if p]
        if parts:
            y = s.get("cosched", {}).get("makespan_cycles", 0.0)
            ax.annotate(
                "\n".join(parts),
                (x[i] + w, y),
                ha="center",
                va="bottom",
                fontsize=6,
            )
    ax.set_xticks(x)
    ax.set_xticklabels(names, fontsize=8)
    ax.set_ylabel("frame makespan (cycles)")
    ax.set_title("Cosched — per-scenario makespan by allocation mode")
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(out, "cosched_makespan.png"), dpi=150)
    plt.close(fig)


def plot_obs(reports, out):
    """Serve timeline from a `--trace-out` export: per-task queue depth and
    per-region utilization over simulated time, for the lowest-numbered
    sim pid in the trace (the first dispatch policy). Degrades gracefully:
    a missing trace.json, a trace without counter samples, or one predating
    a counter track all skip silently.
    """
    data = load(reports, "trace")
    if not data:
        return
    events = data.get("traceEvents")
    if not isinstance(events, list):
        return
    counters = [e for e in events if isinstance(e, dict) and e.get("ph") == "C"]
    if not counters:
        return
    pid = min(e.get("pid", 0) for e in counters)
    series = {}  # track name -> series key -> ([ts_ms], [value])
    for e in counters:
        if e.get("pid") != pid or not isinstance(e.get("args"), dict):
            continue
        for k, v in e["args"].items():
            xs, ys = series.setdefault(e.get("name", "?"), {}).setdefault(k, ([], []))
            xs.append(e.get("ts", 0.0) / 1e3)
            ys.append(v)
    panels = [
        (name, label)
        for name, label in (
            ("queue_depth", "queue depth (requests)"),
            ("region_util", "region utilization"),
        )
        if name in series
    ]
    if not panels:
        return
    fig, axes = plt.subplots(
        len(panels), 1, figsize=(10, 3 * len(panels)), sharex=True, squeeze=False
    )
    for ax, (name, label) in zip(axes[:, 0], panels):
        for key, (xs, ys) in sorted(series[name].items()):
            ax.step(xs, ys, where="post", label=key, alpha=0.8)
        ax.set_ylabel(label)
        ax.legend(fontsize=6, ncol=2)
        ax.grid(alpha=0.3)
    axes[-1, 0].set_xlabel("simulated time (ms)")
    axes[0, 0].set_title(f"Serve timeline — counter tracks from trace.json (pid {pid})")
    fig.tight_layout()
    fig.savefig(os.path.join(out, "obs_timeline.png"), dpi=150)
    plt.close(fig)


def plot_attr(reports, out):
    """Stacked latency-breakdown bars per (scenario, policy, task) from the
    `attr` blocks `pipeorgan serve` embeds in serve.json: mean queue wait,
    compute floor, DRAM-contention stretch and donation credit stack to the
    mean end-to-end latency, with an `x` marking the plan-time predicted
    service floor (compute + DRAM) where the report carries it. Degrades
    gracefully: reports predating the attr block (or runs with attribution
    disabled) skip silently.
    """
    data = load(reports, "serve")
    if not data:
        return
    labels, stacks, preds = [], [], []
    for s in data.get("scenarios") or []:
        for p in s.get("policies") or []:
            attr = p.get("attr")
            if not isinstance(attr, dict):
                continue
            for t in attr.get("tasks") or []:
                parts = [
                    t.get(k)
                    for k in (
                        "mean_queue_ms",
                        "mean_compute_ms",
                        "mean_dram_ms",
                        "mean_donation_ms",
                    )
                ]
                if not all(isinstance(v, (int, float)) for v in parts):
                    continue
                labels.append(
                    f"{s.get('scenario', '?')}\n{p.get('policy', '?')}\n"
                    f"{t.get('name', t.get('task', '?'))}"
                )
                stacks.append(parts)
                pc, pd = t.get("pred_compute_ms"), t.get("pred_dram_ms")
                preds.append(
                    pc + pd
                    if isinstance(pc, (int, float)) and isinstance(pd, (int, float))
                    else None
                )
    if not stacks:
        return
    x = np.arange(len(labels))
    fig, ax = plt.subplots(figsize=(max(6, 1.1 * len(labels)), 4.5))
    bottom = np.zeros(len(labels))
    for i, part in enumerate(("queue wait", "compute floor", "DRAM stretch", "donation")):
        ys = np.array([st[i] for st in stacks])
        ax.bar(x, ys, 0.6, bottom=bottom, label=part)
        bottom += ys
    px = [i for i, v in enumerate(preds) if v is not None]
    if px:
        ax.scatter(
            px,
            [preds[i] for i in px],
            marker="x",
            color="black",
            zorder=3,
            label="predicted service (plan)",
        )
    ax.set_xticks(x)
    ax.set_xticklabels(labels, fontsize=6)
    ax.set_ylabel("mean latency contribution (ms)")
    ax.set_title("Attr — critical-path latency breakdown, observed vs plan-predicted")
    ax.legend(fontsize=7)
    ax.grid(axis="y", alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "attr_breakdown.png"), dpi=150)
    plt.close(fig)


def plot_noc(reports, out):
    """Congestion heatmaps from `pipeorgan-noc-v1` documents: for every
    noc_*.json in the reports dir, the composed/plan entries render as a
    2x2 grid of per-direction link-load heatmaps with idle rectangles
    hatched out. Degrades gracefully: missing files, old reports without
    the schema, or entries without grids all skip silently.
    """
    docs = []
    try:
        names = sorted(os.listdir(reports))
    except OSError:
        return
    for fname in names:
        if not (fname.startswith("noc") and fname.endswith(".json")):
            continue
        data = load(reports, fname[: -len(".json")])
        if isinstance(data, dict) and data.get("schema") == "pipeorgan-noc-v1":
            docs.append((fname[: -len(".json")], data))
    for stem, doc in docs:
        # One figure per non-window entry (plan/region/composed maps);
        # window entries would multiply files without adding structure.
        for e in doc.get("entries") or []:
            if not isinstance(e, dict) or e.get("kind") == "window":
                continue
            rows, cols, grid = e.get("rows"), e.get("cols"), e.get("grid")
            if not (isinstance(rows, int) and isinstance(cols, int) and isinstance(grid, dict)):
                continue
            dirs = ("east", "west", "north", "south")
            if any(
                not isinstance(grid.get(d), list) or len(grid[d]) != rows * cols for d in dirs
            ):
                continue
            vmax = max(e.get("max", 0.0), 1e-12)
            fig, axes = plt.subplots(2, 2, figsize=(8, 7), squeeze=False)
            for ax, d in zip(axes.flat, dirs):
                img = np.array(grid[d], dtype=float).reshape(rows, cols)
                im = ax.imshow(img, origin="upper", cmap="magma", vmin=0.0, vmax=vmax)
                for region in e.get("regions") or []:
                    if not region.get("idle"):
                        continue
                    ax.add_patch(
                        plt.Rectangle(
                            (region["col0"] - 0.5, region["row0"] - 0.5),
                            region["cols"],
                            region["rows"],
                            fill=False,
                            hatch="//",
                            edgecolor="gray",
                            lw=0.5,
                        )
                    )
                ax.set_title(d, fontsize=8)
                ax.set_xticks([])
                ax.set_yticks([])
            fig.colorbar(im, ax=axes.ravel().tolist(), label="words/cycle per link")
            label = e.get("label", "entry")
            verdict = (e.get("verify") or {}).get("congestion_free")
            suffix = {True: " — congestion-free", False: " — SATURATED"}.get(verdict, "")
            fig.suptitle(f"NoC load — {label} ({e.get('topology', '?')}){suffix}", fontsize=10)
            safe = "".join(c if c.isalnum() or c in "-_" else "_" for c in label)
            fig.savefig(os.path.join(out, f"noc_heatmap_{stem}_{safe}.png"), dpi=150)
            plt.close(fig)


def main():
    reports = sys.argv[1] if len(sys.argv) > 1 else "reports"
    out = sys.argv[2] if len(sys.argv) > 2 else reports
    os.makedirs(out, exist_ok=True)
    for fn in (
        plot_fig13,
        plot_fig14,
        plot_fig15,
        plot_fig16,
        plot_fig5,
        plot_cosched,
        plot_obs,
        plot_attr,
        plot_noc,
    ):
        fn(reports, out)
        print(f"{fn.__name__} done")


if __name__ == "__main__":
    main()
