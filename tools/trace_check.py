#!/usr/bin/env python3
"""Validate a Perfetto trace_event JSON file produced by --trace-out.

Usage: python3 tools/trace_check.py [trace_json]

Checks the properties DESIGN.md §Obs promises and ui.perfetto.dev relies
on (the CI trace-smoke step runs this on a fresh `serve --trace-out`):

  - the file is valid JSON with a non-empty traceEvents list;
  - every event (metadata included) carries ph/ts/pid/tid;
  - counter ("C") events have an args object and sample monotonically in
    time per (pid, name) — a counter track that goes back in time renders
    as garbage;
  - the serve timeline's counter tracks (queue_depth, dram_bw,
    region_util, worst_channel_load) are all present;
  - at least one thread_name metadata event names a region track.

Exit status 0 iff the trace passes; failures are listed on stderr.
"""

import json
import sys

REQUIRED_FIELDS = ("ph", "ts", "pid", "tid")
REQUIRED_COUNTERS = ("queue_depth", "dram_bw", "region_util", "worst_channel_load")


def check(doc):
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]

    last_counter_ts = {}
    counter_names = set()
    thread_names = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [k for k in REQUIRED_FIELDS if k not in ev]
        if missing:
            errors.append(f"event {i} ({ev.get('name', '?')}): missing {missing}")
            continue
        ph = ev["ph"]
        if ph == "M" and ev.get("name") == "thread_name":
            thread_names += 1
        if ph != "C":
            continue
        name = ev.get("name", "?")
        counter_names.add(name)
        if not isinstance(ev.get("args"), dict) or not ev["args"]:
            errors.append(f"event {i} ({name}): counter without args series")
        key = (ev["pid"], name)
        ts = ev["ts"]
        prev = last_counter_ts.get(key)
        if prev is not None and ts < prev:
            errors.append(
                f"event {i} ({name}): counter ts {ts} < previous {prev} on pid {ev['pid']}"
            )
        last_counter_ts[key] = ts

    for want in REQUIRED_COUNTERS:
        if want not in counter_names:
            errors.append(f"missing counter track {want} (have: {sorted(counter_names)})")
    if thread_names == 0:
        errors.append("no thread_name metadata events (region tracks would be unnamed)")
    return errors


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/trace.json"
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {path}: {e}", file=sys.stderr)
        return 1

    errors = check(doc)
    events = doc.get("traceEvents") or []
    if errors:
        print(f"trace check FAILED on {path} ({len(errors)} problems):", file=sys.stderr)
        for msg in errors[:25]:
            print(f"  - {msg}", file=sys.stderr)
        if len(errors) > 25:
            print(f"  ... and {len(errors) - 25} more", file=sys.stderr)
        return 1
    dropped = doc.get("droppedEvents", 0)
    suffix = f", {dropped} dropped at the ring cap" if dropped else ""
    print(f"trace check passed: {path} ({len(events)} events{suffix})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
