#!/usr/bin/env python3
"""Validate observability artifacts: --trace-out / --flight-out traces
and --attr-out attribution reports.

Usage: python3 tools/trace_check.py [file ...]    (default reports/trace.json)

Dispatches on document shape:

  - Perfetto trace_event docs (a `traceEvents` list — `--trace-out` and
    `--flight-out` dumps) get the checks DESIGN.md §Obs promises and
    ui.perfetto.dev relies on (the CI trace-smoke step runs this on a
    fresh `serve --trace-out`):
      * valid JSON with a non-empty traceEvents list;
      * every event (metadata included) carries ph/ts/pid/tid;
      * counter ("C") events have an args object and sample
        monotonically in time per (pid, name) — a counter track that
        goes back in time renders as garbage;
      * the serve timeline's counter tracks (queue_depth, dram_bw,
        region_util, worst_channel_load) are all present;
      * at least one thread_name metadata event names a region track.
    A `flight` block (present on `--flight-out` dumps) is additionally
    validated: a known trigger kind, a numeric trigger time, and every
    attribution table row conserving *bit-exactly* — the canonical
    `(((latency − queue) − floor) − stretch) + donation` recompute must
    equal 0.0, which round-trips because both sides serialize floats
    shortest-round-trip (see docs/OBSERVABILITY.md).

  - Attribution reports (`"schema": "pipeorgan-attr-v1"` — `--attr-out`)
    are checked structurally: every policy block carries its
    totals/tasks/regions/windows/burn/worst sections, windows tile the
    span in order, burn-rate samples are time-ordered, and the worst-
    request rows conserve bit-exactly as above.

  - NoC link-load reports (`"schema": "pipeorgan-noc-v1"` — `--noc-out`
    on dse/cosched/serve; see docs/OBSERVABILITY.md §NoC telemetry):
    every entry carries four direction grids of exactly rows × cols
    finite non-negative cells; the maximum over all four grids is
    recomputed in Python and must equal the entry's `max` *bit-exactly*
    (and equal `worst_channel_load` when the entry carries the cost
    scalar — the invariant the Rust tests pin); the p50/p95/max
    distribution is ordered; the verify block is consistent (saturated
    links iff not congestion-free against the threshold); and the listed
    regions (idle rectangles included) stay inside the grid.

Exit status 0 iff every file passes; failures are listed on stderr.
"""

import json
import sys

REQUIRED_FIELDS = ("ph", "ts", "pid", "tid")
REQUIRED_COUNTERS = ("queue_depth", "dram_bw", "region_util", "worst_channel_load")
ATTR_SCHEMA = "pipeorgan-attr-v1"
NOC_SCHEMA = "pipeorgan-noc-v1"
NOC_DIRECTIONS = ("east", "west", "north", "south")
FLIGHT_KINDS = ("deadline_miss", "end_of_run")
ATTR_BLOCK_KEYS = ("totals", "tasks", "regions", "windows", "burn", "worst")


def residual(row):
    """The canonical conservation recompute: exactly 0.0 for every row
    the engine emits (same IEEE-754 ops in the same order)."""
    return (
        ((row["latency_s"] - row["queue_s"]) - row["floor_s"]) - row["stretch_s"]
    ) + row["donation_s"]


def check_attr_rows(rows, where):
    errors = []
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{where}[{i}]: not an object")
            continue
        missing = [
            k
            for k in ("latency_s", "queue_s", "floor_s", "stretch_s", "donation_s")
            if not isinstance(row.get(k), (int, float))
        ]
        if missing:
            errors.append(f"{where}[{i}]: missing numeric {missing}")
            continue
        r = residual(row)
        if r != 0.0:
            errors.append(
                f"{where}[{i}] (task {row.get('task')} id {row.get('id')}): "
                f"conservation residual {r!r} != 0.0"
            )
        if row.get("outcome") not in ("completed", "dropped"):
            errors.append(f"{where}[{i}]: unknown outcome {row.get('outcome')!r}")
    return errors


def check_flight_block(flight):
    errors = []
    if flight.get("kind") not in FLIGHT_KINDS:
        errors.append(f"flight: unknown trigger kind {flight.get('kind')!r}")
    if not isinstance(flight.get("t_s"), (int, float)):
        errors.append("flight: trigger t_s must be numeric")
    table = flight.get("table")
    if not isinstance(table, dict):
        errors.append("flight: missing attribution table")
        return errors
    worst = table.get("worst")
    if not isinstance(worst, list):
        errors.append("flight.table: missing worst list")
    else:
        errors.extend(check_attr_rows(worst, "flight.table.worst"))
    return errors


def check_trace(doc):
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]

    last_counter_ts = {}
    counter_names = set()
    thread_names = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [k for k in REQUIRED_FIELDS if k not in ev]
        if missing:
            errors.append(f"event {i} ({ev.get('name', '?')}): missing {missing}")
            continue
        ph = ev["ph"]
        if ph == "M" and ev.get("name") == "thread_name":
            thread_names += 1
        if ph != "C":
            continue
        name = ev.get("name", "?")
        counter_names.add(name)
        if not isinstance(ev.get("args"), dict) or not ev["args"]:
            errors.append(f"event {i} ({name}): counter without args series")
        key = (ev["pid"], name)
        ts = ev["ts"]
        prev = last_counter_ts.get(key)
        if prev is not None and ts < prev:
            errors.append(
                f"event {i} ({name}): counter ts {ts} < previous {prev} on pid {ev['pid']}"
            )
        last_counter_ts[key] = ts

    for want in REQUIRED_COUNTERS:
        if want not in counter_names:
            errors.append(f"missing counter track {want} (have: {sorted(counter_names)})")
    if thread_names == 0:
        errors.append("no thread_name metadata events (region tracks would be unnamed)")
    if isinstance(doc.get("flight"), dict):
        errors.extend(check_flight_block(doc["flight"]))
    return errors


def check_attr_report(doc):
    errors = []
    scenarios = doc.get("scenarios")
    if not isinstance(scenarios, list) or not scenarios:
        return ["attr report: scenarios must be a non-empty list"]
    for s in scenarios:
        name = s.get("scenario", "?")
        for p in s.get("policies") or []:
            where = f"{name}/{p.get('policy', '?')}"
            for key in ATTR_BLOCK_KEYS:
                if key not in p:
                    errors.append(f"{where}: missing {key} section")
            windows = p.get("windows") or []
            ok_windows = all(
                isinstance(w.get("t0_s"), (int, float)) and isinstance(w.get("t1_s"), (int, float))
                for w in windows
            )
            if not ok_windows:
                errors.append(f"{where}: windows must carry numeric t0_s/t1_s")
            else:
                for i, w in enumerate(windows):
                    if not w["t0_s"] < w["t1_s"]:
                        errors.append(f"{where}: window {i} is empty or inverted")
                for a, b in zip(windows, windows[1:]):
                    if a["t1_s"] != b["t0_s"]:
                        errors.append(
                            f"{where}: windows must tile the span ({a['t1_s']} vs {b['t0_s']})"
                        )
            burn = p.get("burn") or []
            if not all(isinstance(b.get("t_s"), (int, float)) for b in burn):
                errors.append(f"{where}: burn samples must carry numeric t_s")
            else:
                for a, b in zip(burn, burn[1:]):
                    if not a["t_s"] < b["t_s"]:
                        errors.append(f"{where}: burn samples must be time-ordered")
                        break
            for b in burn:
                if not isinstance(b.get("burn_rate"), (int, float)) or b["burn_rate"] < 0:
                    errors.append(f"{where}: burn_rate must be a non-negative number")
                    break
            errors.extend(check_attr_rows(p.get("worst") or [], f"{where}.worst"))
    return errors


def check_noc_report(doc):
    errors = []
    if doc.get("source") not in ("dse", "cosched", "serve"):
        errors.append(f"noc report: unknown source {doc.get('source')!r}")
    if not isinstance(doc.get("link_words_per_cycle"), (int, float)):
        errors.append("noc report: link_words_per_cycle must be numeric")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        return errors + ["noc report: entries must be a non-empty list"]
    for e in entries:
        label = e.get("label", "?")
        rows, cols = e.get("rows"), e.get("cols")
        if not (isinstance(rows, int) and isinstance(cols, int) and rows > 0 and cols > 0):
            errors.append(f"{label}: rows/cols must be positive integers")
            continue
        grid = e.get("grid")
        if not isinstance(grid, dict):
            errors.append(f"{label}: missing grid block")
            continue
        grid_max = 0.0
        for d in NOC_DIRECTIONS:
            cells = grid.get(d)
            if not isinstance(cells, list) or len(cells) != rows * cols:
                errors.append(f"{label}: {d} grid must have exactly {rows * cols} cells")
                continue
            bad = [w for w in cells if not isinstance(w, (int, float)) or w < 0]
            if bad:
                errors.append(f"{label}: {d} grid has non-numeric/negative cells")
                continue
            grid_max = max(grid_max, max(cells, default=0.0))
        # The tentpole invariant, recomputed independently: the grids'
        # max must equal the reported max — and the cost-model scalar
        # when present — with no tolerance (every aggregation on the
        # Rust side is an exact f64::max fold).
        if grid_max != e.get("max"):
            errors.append(f"{label}: grid max {grid_max!r} != reported max {e.get('max')!r}")
        if "worst_channel_load" in e and e["worst_channel_load"] != e.get("max"):
            errors.append(
                f"{label}: worst_channel_load {e['worst_channel_load']!r} "
                f"!= map max {e.get('max')!r}"
            )
        p50, p95 = e.get("p50"), e.get("p95")
        if not (
            isinstance(p50, (int, float))
            and isinstance(p95, (int, float))
            and p50 <= p95 <= e.get("max", float("-inf"))
        ):
            errors.append(f"{label}: p50/p95/max must be numeric and ordered")
        verify = e.get("verify")
        links = e.get("links")
        if not isinstance(verify, dict) or not isinstance(links, dict):
            errors.append(f"{label}: missing verify/links blocks")
        else:
            saturated = links.get("saturated")
            free = verify.get("congestion_free")
            if not isinstance(saturated, int) or not isinstance(free, bool):
                errors.append(f"{label}: saturated/congestion_free have wrong types")
            elif free != (saturated == 0):
                errors.append(
                    f"{label}: congestion_free={free} inconsistent with "
                    f"{saturated} saturated links"
                )
        for i, region in enumerate(e.get("regions") or []):
            try:
                inside = (
                    region["row0"] + region["rows"] <= rows
                    and region["col0"] + region["cols"] <= cols
                )
            except (KeyError, TypeError):
                errors.append(f"{label}: region {i} missing row0/col0/rows/cols")
                continue
            if not inside:
                errors.append(f"{label}: region {i} ({region.get('label')}) exceeds the grid")
        window = e.get("window")
        if window is not None and not (
            isinstance(window, dict)
            and isinstance(window.get("t0_s"), (int, float))
            and isinstance(window.get("t1_s"), (int, float))
            and window["t0_s"] < window["t1_s"]
        ):
            errors.append(f"{label}: window must carry t0_s < t1_s")
    return errors


def check(doc):
    if isinstance(doc.get("traceEvents"), list):
        return check_trace(doc)
    if doc.get("schema") == ATTR_SCHEMA:
        return check_attr_report(doc)
    if doc.get("schema") == NOC_SCHEMA:
        return check_noc_report(doc)
    return [
        "unrecognized document: not a trace (traceEvents), attr report, or noc report (schema)"
    ]


def describe(doc):
    events = doc.get("traceEvents")
    if isinstance(events, list):
        dropped = doc.get("droppedEvents", 0)
        suffix = f", {dropped} dropped at the ring cap" if dropped else ""
        if isinstance(doc.get("flight"), dict):
            suffix += f", flight trigger {doc['flight'].get('kind')}"
        return f"{len(events)} events{suffix}"
    if doc.get("schema") == NOC_SCHEMA:
        entries = doc.get("entries") or []
        saturated = sum(
            (e.get("links") or {}).get("saturated", 0)
            for e in entries
            if isinstance(e, dict)
        )
        return (
            f"noc report ({doc.get('source')}), {len(entries)} entries, "
            f"{saturated} saturated links"
        )
    policies = sum(len(s.get("policies") or []) for s in doc.get("scenarios") or [])
    return f"attr report, {policies} policy blocks"


def main():
    paths = sys.argv[1:] or ["reports/trace.json"]
    failed = False
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: {path}: {e}", file=sys.stderr)
            failed = True
            continue
        errors = check(doc)
        if errors:
            failed = True
            print(f"trace check FAILED on {path} ({len(errors)} problems):", file=sys.stderr)
            for msg in errors[:25]:
                print(f"  - {msg}", file=sys.stderr)
            if len(errors) > 25:
                print(f"  ... and {len(errors) - 25} more", file=sys.stderr)
        else:
            print(f"trace check passed: {path} ({describe(doc)})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
